//! A bounded, blocking MPMC job queue with real admission control.
//!
//! This is the "persistent leader/streaming job queue" the ROADMAP
//! perf log called for: before it, `CoordinatorConfig::queue_depth`
//! was documentation-only because jobs were drained from an in-memory
//! `Vec` through a shared cursor.  [`JobQueue`] makes the depth a real
//! backpressure bound — producers either block ([`JobQueue::push`]) or
//! get [`PushError::Busy`] back ([`JobQueue::try_push`]) when the queue
//! is full, so an I/O-bound producer can never race arbitrarily far
//! ahead of the compute workers.
//!
//! # Lifecycle
//!
//! A queue is open until [`JobQueue::close`] (graceful drain: no new
//! pushes are admitted, consumers keep popping until the backlog is
//! empty, then [`JobQueue::pop`] returns `None`) or [`JobQueue::abort`]
//! (close **and** discard the backlog, returning the unprocessed items
//! to the caller so it can fail them explicitly).  Both are idempotent.
//!
//! # Instrumentation
//!
//! The queue tracks its own gauges — current depth, high-water mark,
//! producer block/busy events, totals — snapshotted by
//! [`JobQueue::stats`].  The coordinator and the server fold these into
//! [`crate::coordinator::Metrics`] so `MetricsSummary` finally shows
//! whether `queue_depth` is actually exerting backpressure.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// Why a non-blocking push was refused.  The item is handed back so the
/// producer can retry, run it in-line, or drop it deliberately.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at `depth`: admission control says try again later
    /// (or help drain).
    Busy(T),
    /// The queue was closed or aborted: no further work is admitted.
    Closed(T),
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Monotonic counters shared by all queue handles (lock-free reads).
#[derive(Debug, Default)]
struct QueueCounters {
    high_water: AtomicU64,
    producer_blocks: AtomicU64,
    pushed: AtomicU64,
    popped: AtomicU64,
}

/// Point-in-time view of the queue gauges.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    /// Items currently queued (admitted, not yet popped).
    pub depth: u64,
    /// Maximum depth ever observed.
    pub high_water: u64,
    /// Times a producer was refused admission (blocking pushes that had
    /// to wait, plus `try_push` calls that returned [`PushError::Busy`]).
    pub producer_blocks: u64,
    /// Items admitted over the queue's lifetime.
    pub pushed: u64,
    /// Items handed to consumers over the queue's lifetime.
    pub popped: u64,
}

/// A bounded blocking MPMC queue.  See the module docs for the
/// lifecycle and backpressure semantics.
pub struct JobQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    depth: usize,
    counters: QueueCounters,
}

impl<T> JobQueue<T> {
    /// A queue admitting at most `depth` items (clamped to ≥ 1).
    pub fn new(depth: usize) -> JobQueue<T> {
        JobQueue {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            depth: depth.max(1),
            counters: QueueCounters::default(),
        }
    }

    /// Configured capacity bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`JobQueue::close`] or [`JobQueue::abort`] has run.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    fn admitted(&self, new_len: usize) {
        self.counters.pushed.fetch_add(1, Ordering::Relaxed);
        self.counters.high_water.fetch_max(new_len as u64, Ordering::Relaxed);
    }

    /// Admit `item`, returning `Err(item)` if the queue is closed.
    /// **Blocks while the queue is full** — this is the admission
    /// control path for producers that may safely sleep (e.g. a socket
    /// reader).  Producers that must stay deadlock-free under a shared
    /// worker pool should use [`JobQueue::try_push`] and help drain on
    /// [`PushError::Busy`] instead.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        let mut counted_block = false;
        loop {
            if inner.closed {
                return Err(item);
            }
            if inner.items.len() < self.depth {
                inner.items.push_back(item);
                self.admitted(inner.items.len());
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            if !counted_block {
                self.counters.producer_blocks.fetch_add(1, Ordering::Relaxed);
                counted_block = true;
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Admit `item` without blocking: [`PushError::Busy`] when full,
    /// [`PushError::Closed`] after close/abort.  A `Busy` refusal counts
    /// as one producer block in the stats.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.items.len() >= self.depth {
            self.counters.producer_blocks.fetch_add(1, Ordering::Relaxed);
            return Err(PushError::Busy(item));
        }
        inner.items.push_back(item);
        self.admitted(inner.items.len());
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Take the oldest item, blocking while the queue is open but
    /// empty.  Returns `None` once the queue is closed **and** drained
    /// — the consumer's termination signal.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                self.counters.popped.fetch_add(1, Ordering::Relaxed);
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Take the oldest item without blocking (`None` when empty,
    /// whether or not the queue is closed).
    pub fn try_pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let item = inner.items.pop_front()?;
        self.counters.popped.fetch_add(1, Ordering::Relaxed);
        drop(inner);
        self.not_full.notify_one();
        Some(item)
    }

    /// Remove and return the **first queued item matching `pred`**,
    /// without blocking.  This is the micro-batching hook: a worker
    /// that popped a small request can opportunistically pull further
    /// compatible requests (same profile, same engine) and run them
    /// through one frozen coefficient table.
    pub fn try_pop_where<P: FnMut(&T) -> bool>(&self, mut pred: P) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let pos = inner.items.iter().position(&mut pred)?;
        let item = inner.items.remove(pos)?;
        self.counters.popped.fetch_add(1, Ordering::Relaxed);
        drop(inner);
        self.not_full.notify_one();
        Some(item)
    }

    /// Graceful drain: refuse new work, let consumers empty the
    /// backlog, then report exhaustion (`pop` → `None`).  Idempotent.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Close **and discard** the backlog, returning the unprocessed
    /// items so the caller can fail them explicitly (the server sends
    /// an `aborted` response for each).  Idempotent; a second call
    /// returns an empty vec.
    pub fn abort(&self) -> Vec<T> {
        let dropped: Vec<T> = {
            let mut inner = self.inner.lock().unwrap();
            inner.closed = true;
            inner.items.drain(..).collect()
        };
        self.not_full.notify_all();
        self.not_empty.notify_all();
        dropped
    }

    /// Snapshot the gauges.
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            depth: self.len() as u64,
            high_water: self.counters.high_water.load(Ordering::Relaxed),
            producer_blocks: self.counters.producer_blocks.load(Ordering::Relaxed),
            pushed: self.counters.pushed.load(Ordering::Relaxed),
            popped: self.counters.popped.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn fifo_within_capacity() {
        let q = JobQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        let s = q.stats();
        assert_eq!(s.pushed, 4);
        assert_eq!(s.popped, 4);
        assert_eq!(s.high_water, 4);
        assert_eq!(s.producer_blocks, 0);
    }

    #[test]
    fn try_push_refuses_when_full_and_counts_blocks() {
        let q = JobQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Busy(3)) => {}
            other => panic!("expected Busy(3), got {other:?}"),
        }
        assert_eq!(q.stats().producer_blocks, 1);
        // Draining one item re-admits.
        assert_eq!(q.try_pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q = Arc::new(JobQueue::new(1));
        q.push(0usize).unwrap();
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 1..=20usize {
                    q.push(i).unwrap();
                }
            })
        };
        let mut seen = Vec::new();
        for _ in 0..=20 {
            seen.push(q.pop().unwrap());
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..=20).collect::<Vec<_>>());
        let s = q.stats();
        assert!(s.high_water <= 1, "depth bound violated: {}", s.high_water);
        assert!(s.producer_blocks > 0, "producer never blocked");
    }

    #[test]
    fn close_drains_then_signals_exhaustion() {
        let q = JobQueue::new(8);
        q.push('a').unwrap();
        q.push('b').unwrap();
        q.close();
        assert!(q.is_closed());
        assert!(q.push('c').is_err());
        assert!(matches!(q.try_push('c'), Err(PushError::Closed('c'))));
        assert_eq!(q.pop(), Some('a'));
        assert_eq!(q.pop(), Some('b'));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None); // idempotent exhaustion
    }

    #[test]
    fn abort_returns_the_backlog() {
        let q = JobQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.try_pop(), Some(0));
        let dropped = q.abort();
        assert_eq!(dropped, vec![1, 2, 3, 4]);
        assert_eq!(q.pop(), None);
        assert!(q.abort().is_empty());
    }

    #[test]
    fn close_unblocks_waiting_consumers() {
        let q = Arc::new(JobQueue::<u32>::new(2));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut n = 0usize;
                    while q.pop().is_some() {
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        for i in 0..10 {
            q.push(i).unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn try_pop_where_picks_matching_item() {
        let q = JobQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.try_pop_where(|&i| i % 2 == 1), Some(1));
        assert_eq!(q.try_pop_where(|&i| i % 2 == 1), Some(3));
        assert_eq!(q.try_pop_where(|&i| i > 10), None);
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
    }

    #[test]
    fn mpmc_under_contention_delivers_every_item_once() {
        let q = Arc::new(JobQueue::new(4));
        let delivered = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                let delivered = Arc::clone(&delivered);
                std::thread::spawn(move || {
                    while q.pop().is_some() {
                        delivered.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(delivered.load(Ordering::Relaxed), 200);
        let s = q.stats();
        assert_eq!(s.pushed, 200);
        assert_eq!(s.popped, 200);
        assert!(s.high_water <= 4);
    }
}
