//! A bounded, blocking MPMC job queue with real admission control.
//!
//! This is the "persistent leader/streaming job queue" the ROADMAP
//! perf log called for: before it, `CoordinatorConfig::queue_depth`
//! was documentation-only because jobs were drained from an in-memory
//! `Vec` through a shared cursor.  [`JobQueue`] makes the depth a real
//! backpressure bound — producers either block ([`JobQueue::push`]) or
//! get [`PushError::Busy`] back ([`JobQueue::try_push`]) when the queue
//! is full, so an I/O-bound producer can never race arbitrarily far
//! ahead of the compute workers.
//!
//! # Lifecycle
//!
//! A queue is open until [`JobQueue::close`] (graceful drain: no new
//! pushes are admitted, consumers keep popping until the backlog is
//! empty, then [`JobQueue::pop`] returns `None`) or [`JobQueue::abort`]
//! (close **and** discard the backlog, returning the unprocessed items
//! to the caller so it can fail them explicitly).  Both are idempotent.
//!
//! # Instrumentation
//!
//! The queue tracks its own gauges — current depth, high-water mark,
//! producer block/busy events, totals — snapshotted by
//! [`JobQueue::stats`].  The coordinator and the server fold these into
//! [`crate::coordinator::Metrics`] so `MetricsSummary` finally shows
//! whether `queue_depth` is actually exerting backpressure.
//!
//! # Tenant-aware admission ([`TenantQueue`])
//!
//! The serving layer needs more than a single bounded FIFO: one tenant
//! flooding the queue must not starve everyone else.  [`TenantQueue`]
//! layers two policies on the same blocking MPMC core:
//!
//! * **Per-tenant quotas** ([`TenantQuota`]): a cap on how many
//!   requests a tenant may have *queued* and a cap on how many may be
//!   *in flight* (popped but not yet [`TenantQueue::finish`]ed).  A
//!   tenant at its queued cap gets [`AdmitError::AtQuota`] back while
//!   other tenants still admit; a tenant at its in-flight cap simply
//!   isn't popped until one of its requests finishes (other tenants'
//!   work flows past it).
//! * **Priority classes** ([`Priority`]): a small fixed set of classes
//!   popped high-first, FIFO within each class.
//!
//! The coordinator keeps using the plain [`JobQueue`] (its single
//! producer is itself); the server's [`crate::server::Server`] runs on
//! [`TenantQueue`] and folds the per-tenant gauges into
//! [`crate::coordinator::Metrics`].

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// Why a non-blocking push was refused.  The item is handed back so the
/// producer can retry, run it in-line, or drop it deliberately.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at `depth`: admission control says try again later
    /// (or help drain).
    Busy(T),
    /// The queue was closed or aborted: no further work is admitted.
    Closed(T),
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Monotonic counters shared by all queue handles (lock-free reads).
#[derive(Debug, Default)]
struct QueueCounters {
    high_water: AtomicU64,
    producer_blocks: AtomicU64,
    pushed: AtomicU64,
    popped: AtomicU64,
}

/// Point-in-time view of the queue gauges.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    /// Items currently queued (admitted, not yet popped).
    pub depth: u64,
    /// Maximum depth ever observed.
    pub high_water: u64,
    /// Times a producer was refused admission (blocking pushes that had
    /// to wait, plus `try_push` calls that returned [`PushError::Busy`]).
    pub producer_blocks: u64,
    /// Items admitted over the queue's lifetime.
    pub pushed: u64,
    /// Items handed to consumers over the queue's lifetime.
    pub popped: u64,
}

/// A bounded blocking MPMC queue.  See the module docs for the
/// lifecycle and backpressure semantics.
pub struct JobQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    depth: usize,
    counters: QueueCounters,
}

impl<T> JobQueue<T> {
    /// A queue admitting at most `depth` items (clamped to ≥ 1).
    pub fn new(depth: usize) -> JobQueue<T> {
        JobQueue {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            depth: depth.max(1),
            counters: QueueCounters::default(),
        }
    }

    /// Configured capacity bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`JobQueue::close`] or [`JobQueue::abort`] has run.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    fn admitted(&self, new_len: usize) {
        self.counters.pushed.fetch_add(1, Ordering::Relaxed);
        self.counters.high_water.fetch_max(new_len as u64, Ordering::Relaxed);
    }

    /// Admit `item`, returning `Err(item)` if the queue is closed.
    /// **Blocks while the queue is full** — this is the admission
    /// control path for producers that may safely sleep (e.g. a socket
    /// reader).  Producers that must stay deadlock-free under a shared
    /// worker pool should use [`JobQueue::try_push`] and help drain on
    /// [`PushError::Busy`] instead.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        let mut counted_block = false;
        loop {
            if inner.closed {
                return Err(item);
            }
            if inner.items.len() < self.depth {
                inner.items.push_back(item);
                self.admitted(inner.items.len());
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            if !counted_block {
                self.counters.producer_blocks.fetch_add(1, Ordering::Relaxed);
                counted_block = true;
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Admit `item` without blocking: [`PushError::Busy`] when full,
    /// [`PushError::Closed`] after close/abort.  A `Busy` refusal counts
    /// as one producer block in the stats.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.items.len() >= self.depth {
            self.counters.producer_blocks.fetch_add(1, Ordering::Relaxed);
            return Err(PushError::Busy(item));
        }
        inner.items.push_back(item);
        self.admitted(inner.items.len());
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Take the oldest item, blocking while the queue is open but
    /// empty.  Returns `None` once the queue is closed **and** drained
    /// — the consumer's termination signal.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                self.counters.popped.fetch_add(1, Ordering::Relaxed);
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Take the oldest item without blocking (`None` when empty,
    /// whether or not the queue is closed).
    pub fn try_pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let item = inner.items.pop_front()?;
        self.counters.popped.fetch_add(1, Ordering::Relaxed);
        drop(inner);
        self.not_full.notify_one();
        Some(item)
    }

    /// Remove and return the **first queued item matching `pred`**,
    /// without blocking.  This is the micro-batching hook: a worker
    /// that popped a small request can opportunistically pull further
    /// compatible requests (same profile, same engine) and run them
    /// through one frozen coefficient table.
    pub fn try_pop_where<P: FnMut(&T) -> bool>(&self, mut pred: P) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let pos = inner.items.iter().position(&mut pred)?;
        let item = inner.items.remove(pos)?;
        self.counters.popped.fetch_add(1, Ordering::Relaxed);
        drop(inner);
        self.not_full.notify_one();
        Some(item)
    }

    /// Graceful drain: refuse new work, let consumers empty the
    /// backlog, then report exhaustion (`pop` → `None`).  Idempotent.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Close **and discard** the backlog, returning the unprocessed
    /// items so the caller can fail them explicitly (the server sends
    /// an `aborted` response for each).  Idempotent; a second call
    /// returns an empty vec.
    pub fn abort(&self) -> Vec<T> {
        let dropped: Vec<T> = {
            let mut inner = self.inner.lock().unwrap();
            inner.closed = true;
            inner.items.drain(..).collect()
        };
        self.not_full.notify_all();
        self.not_empty.notify_all();
        dropped
    }

    /// Snapshot the gauges.
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            depth: self.len() as u64,
            high_water: self.counters.high_water.load(Ordering::Relaxed),
            producer_blocks: self.counters.producer_blocks.load(Ordering::Relaxed),
            pushed: self.counters.pushed.load(Ordering::Relaxed),
            popped: self.counters.popped.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------
// Tenant-aware admission layer.
// ---------------------------------------------------------------------

/// Priority class of a request.  A small fixed set, popped high-first;
/// FIFO within one class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    /// Background / batch work: served only when nothing more urgent
    /// is queued.
    Low,
    /// The default class.
    #[default]
    Normal,
    /// Latency-sensitive work, always popped first.
    High,
}

impl Priority {
    /// Number of priority classes.
    pub const N_CLASSES: usize = 3;

    /// Canonical lowercase name (wire protocol / config value).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a canonical name.
    pub fn parse(name: &str) -> Option<Priority> {
        match name {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    /// Pop-order class index: 0 is popped first.
    fn class(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Per-tenant admission caps.  The same quota applies to every tenant
/// (fair by symmetry); `usize::MAX` on both fields disables quotas.
#[derive(Clone, Copy, Debug)]
pub struct TenantQuota {
    /// Maximum requests one tenant may have waiting in the queue.  At
    /// the cap, non-blocking admission returns
    /// [`AdmitError::AtQuota`]; blocking admission waits.
    pub max_queued: usize,
    /// Maximum requests one tenant may have in flight (popped but not
    /// yet [`TenantQueue::finish`]ed).  At the cap the tenant's queued
    /// requests are skipped by consumers, letting other tenants' work
    /// through, until one of its in-flight requests finishes.
    pub max_in_flight: usize,
}

impl Default for TenantQuota {
    /// Unlimited — single-tenant callers see the plain bounded-queue
    /// behavior.
    fn default() -> Self {
        TenantQuota { max_queued: usize::MAX, max_in_flight: usize::MAX }
    }
}

/// Why tenant-aware admission refused an item (handed back).
#[derive(Debug)]
pub enum AdmitError<T> {
    /// The queue is globally full; any tenant would be refused.
    Busy(T),
    /// *This tenant* is at its queued cap; other tenants still admit.
    AtQuota(T),
    /// Load shedding: the queue is past its high-water shed limit and
    /// the item is low-priority — refused early instead of letting it
    /// crowd out latency-sensitive work (see
    /// [`TenantQueue::new_with_shed`]).
    Shed(T),
    /// The request's estimated full-matrix forward scratch exceeds the
    /// server's memory budget (`serve.max_scratch_bytes`) and
    /// checkpointing is disabled, so running it would risk an OOM.
    /// Produced by the serving layer's admission estimate, never by
    /// the queue itself; re-submit with checkpointing enabled
    /// (`train.scratch_mode = checkpointed | auto`) or shorter reads.
    OverMemoryBudget(T),
    /// The queue was closed or aborted.
    Closed(T),
}

/// Point-in-time per-tenant gauges.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantStats {
    /// Requests currently queued for this tenant.
    pub queued: u64,
    /// Requests popped but not yet finished.
    pub in_flight: u64,
    /// Requests admitted over the queue's lifetime.
    pub admitted: u64,
    /// Admissions refused (or blocked) because the tenant was at a
    /// quota cap — the "your quota, not the server" signal.
    pub quota_refusals: u64,
    /// Requests finished ([`TenantQueue::finish`]) over the lifetime.
    pub finished: u64,
    /// Low-priority admissions refused by load shedding.  Only counted
    /// for tenants the queue already tracks (a shed refusal must never
    /// create a gauge entry — tenant ids are client-controlled).
    pub shed: u64,
}

#[derive(Default)]
struct TenantCount {
    queued: usize,
    in_flight: usize,
    admitted: u64,
    quota_refusals: u64,
    finished: u64,
    shed: u64,
}

/// Bound on distinct tenants tracked in the gauge maps — this one and
/// the mirror map in [`crate::coordinator::Metrics`], which imports
/// the same constant so the two evict at the same threshold.  Tenant
/// ids are client-controlled, so without a cap a client cycling fresh
/// ids would grow the maps (and every stats snapshot) without limit.
/// When the cap is exceeded, *idle* entries (nothing queued or in
/// flight) are evicted; an evicted tenant that returns simply restarts
/// its lifetime counters from zero.
pub(crate) const MAX_TRACKED_TENANTS: usize = 1024;

struct TenantInner<T> {
    /// One FIFO per priority class, indexed by [`Priority::class`]
    /// (0 popped first).
    classes: [VecDeque<(String, T)>; Priority::N_CLASSES],
    /// Per-tenant accounting, keyed by tenant id (BTreeMap for
    /// deterministic snapshot order).
    tenants: BTreeMap<String, TenantCount>,
    closed: bool,
}

impl<T> TenantInner<T> {
    fn total_len(&self) -> usize {
        self.classes.iter().map(|c| c.len()).sum()
    }
}

/// A bounded blocking MPMC queue with per-tenant quotas and priority
/// classes — the serving layer's admission-control core.  See the
/// module docs for the policy and [`JobQueue`] for the lifecycle
/// semantics it inherits (close/abort, blocking pop, gauges).
pub struct TenantQueue<T> {
    inner: Mutex<TenantInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    depth: usize,
    quota: TenantQuota,
    /// Load-shedding high-water mark in items: once the total backlog
    /// reaches this, non-blocking low-priority admission is refused
    /// with [`AdmitError::Shed`].  `0` disables shedding.
    shed_limit: usize,
    counters: QueueCounters,
}

impl<T> TenantQueue<T> {
    /// A queue admitting at most `depth` items in total (clamped ≥ 1),
    /// with `quota` applied to every tenant (caps clamped ≥ 1 — a
    /// zero cap would deadlock consumers on permanently unpoppable
    /// items).  Load shedding is off; see
    /// [`TenantQueue::new_with_shed`].
    pub fn new(depth: usize, quota: TenantQuota) -> TenantQueue<T> {
        TenantQueue::new_with_shed(depth, quota, 0)
    }

    /// [`TenantQueue::new`] plus a load-shedding high-water mark:
    /// while `total_len() >= shed_limit`, [`TenantQueue::try_push`]
    /// refuses [`Priority::Low`] items with [`AdmitError::Shed`]
    /// instead of queueing them behind everyone else (normal/high
    /// items still admit up to `depth`).  `shed_limit = 0` disables
    /// shedding; the blocking [`TenantQueue::push`] path is never
    /// shed — streaming producers feel backpressure instead.
    pub fn new_with_shed(depth: usize, quota: TenantQuota, shed_limit: usize) -> TenantQueue<T> {
        TenantQueue {
            inner: Mutex::new(TenantInner {
                classes: std::array::from_fn(|_| VecDeque::new()),
                tenants: BTreeMap::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            depth: depth.max(1),
            quota: TenantQuota {
                max_queued: quota.max_queued.max(1),
                max_in_flight: quota.max_in_flight.max(1),
            },
            shed_limit,
            counters: QueueCounters::default(),
        }
    }

    /// Load-shedding high-water mark in items (0 = shedding off).
    pub fn shed_limit(&self) -> usize {
        self.shed_limit
    }

    /// Configured global capacity bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Per-tenant caps in force.
    pub fn quota(&self) -> TenantQuota {
        self.quota
    }

    /// Items currently queued across all tenants and classes.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().total_len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`TenantQueue::close`] or [`TenantQueue::abort`] has
    /// run.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    fn admit(&self, inner: &mut TenantInner<T>, tenant: &str, priority: Priority, item: T) {
        let t = inner.tenants.entry(tenant.to_string()).or_default();
        t.queued += 1;
        t.admitted += 1;
        // Admission is the only place a tenant entry is created (the
        // refusal paths require an existing queued count and finish()
        // only updates existing entries), so the cap check here bounds
        // the map.  A just-admitted tenant has queued >= 1 and is
        // never idle, so it cannot evict itself.
        if inner.tenants.len() > MAX_TRACKED_TENANTS {
            inner.tenants.retain(|_, t| t.queued > 0 || t.in_flight > 0);
        }
        inner.classes[priority.class()].push_back((tenant.to_string(), item));
        self.counters.pushed.fetch_add(1, Ordering::Relaxed);
        self.counters.high_water.fetch_max(inner.total_len() as u64, Ordering::Relaxed);
    }

    /// Admit without blocking.  Checks the tenant's queued cap first
    /// (so an at-quota tenant sees [`AdmitError::AtQuota`] even when
    /// the queue is also full), then the global depth.
    pub fn try_push(
        &self,
        tenant: &str,
        priority: Priority,
        item: T,
    ) -> std::result::Result<(), AdmitError<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(AdmitError::Closed(item));
        }
        let queued = inner.tenants.get(tenant).map_or(0, |t| t.queued);
        if queued >= self.quota.max_queued {
            inner.tenants.entry(tenant.to_string()).or_default().quota_refusals += 1;
            return Err(AdmitError::AtQuota(item));
        }
        if self.shed_limit > 0
            && priority == Priority::Low
            && inner.total_len() >= self.shed_limit
        {
            // Attribute the shed only to already-tracked tenants:
            // unlike AtQuota (which requires an existing queued count),
            // shedding can hit a brand-new tenant, and a refusal must
            // never create a gauge entry for a client-controlled id.
            if let Some(t) = inner.tenants.get_mut(tenant) {
                t.shed += 1;
            }
            return Err(AdmitError::Shed(item));
        }
        if inner.total_len() >= self.depth {
            self.counters.producer_blocks.fetch_add(1, Ordering::Relaxed);
            return Err(AdmitError::Busy(item));
        }
        self.admit(&mut inner, tenant, priority, item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Admit, blocking while the queue is globally full **or** the
    /// tenant is at its queued cap (streaming clients feel quota
    /// pressure as backpressure, not errors — sheddable producers use
    /// [`TenantQueue::try_push`]).  Returns `Err(item)` once closed.
    pub fn push(&self, tenant: &str, priority: Priority, item: T) -> std::result::Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        let mut counted_block = false;
        let mut counted_quota = false;
        loop {
            if inner.closed {
                return Err(item);
            }
            let queued = inner.tenants.get(tenant).map_or(0, |t| t.queued);
            let at_quota = queued >= self.quota.max_queued;
            let full = inner.total_len() >= self.depth;
            if !at_quota && !full {
                self.admit(&mut inner, tenant, priority, item);
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            if at_quota && !counted_quota {
                inner.tenants.entry(tenant.to_string()).or_default().quota_refusals += 1;
                counted_quota = true;
            }
            if full && !counted_block {
                self.counters.producer_blocks.fetch_add(1, Ordering::Relaxed);
                counted_block = true;
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Pop the next eligible item under the scheduling policy: highest
    /// priority class first, FIFO within the class, skipping items
    /// whose tenant is at its in-flight cap.  Increments the tenant's
    /// in-flight count — the consumer **must** call
    /// [`TenantQueue::finish`] when done, or the tenant wedges at its
    /// cap.  Blocks while the queue is open and nothing is eligible;
    /// returns `None` once closed **and** drained.
    pub fn pop(&self) -> Option<(String, T)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(pair) = self.take_eligible(&mut inner, |_| true) {
                drop(inner);
                self.not_full.notify_all();
                // Fault-injection site: fires with the lock released,
                // after the item is charged in flight — exactly where a
                // worker would start executing it.
                crate::failpoint!("queue::pop");
                return Some(pair);
            }
            if inner.closed && inner.total_len() == 0 {
                return None;
            }
            // Either empty, or every queued item belongs to a tenant at
            // its in-flight cap: wait for a push or a finish.
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Non-blocking [`TenantQueue::pop`]: `None` when nothing is
    /// eligible right now.
    pub fn try_pop(&self) -> Option<(String, T)> {
        let mut inner = self.inner.lock().unwrap();
        let pair = self.take_eligible(&mut inner, |_| true)?;
        drop(inner);
        self.not_full.notify_all();
        Some(pair)
    }

    /// Remove the first eligible queued item matching `pred` (the
    /// micro-batching hook — see [`JobQueue::try_pop_where`]).  The
    /// same in-flight accounting applies: a match from a tenant at its
    /// cap is skipped, and a returned item must be
    /// [`TenantQueue::finish`]ed.
    pub fn try_pop_where<P: FnMut(&T) -> bool>(&self, pred: P) -> Option<(String, T)> {
        let mut inner = self.inner.lock().unwrap();
        let pair = self.take_eligible(&mut inner, pred)?;
        drop(inner);
        self.not_full.notify_all();
        Some(pair)
    }

    /// Scan classes high-priority-first for the first item that
    /// matches `pred` and whose tenant is under its in-flight cap;
    /// remove it and charge the tenant's in-flight count.
    fn take_eligible<P: FnMut(&T) -> bool>(
        &self,
        inner: &mut TenantInner<T>,
        mut pred: P,
    ) -> Option<(String, T)> {
        let mut found: Option<(usize, usize)> = None;
        'classes: for (c, class) in inner.classes.iter().enumerate() {
            for (i, (tenant, item)) in class.iter().enumerate() {
                let in_flight = inner.tenants.get(tenant).map_or(0, |t| t.in_flight);
                if in_flight >= self.quota.max_in_flight {
                    continue;
                }
                if pred(item) {
                    found = Some((c, i));
                    break 'classes;
                }
            }
        }
        let (c, i) = found?;
        let (tenant, item) = inner.classes[c].remove(i).expect("scanned index in range");
        let t = inner.tenants.entry(tenant.clone()).or_default();
        t.queued = t.queued.saturating_sub(1);
        t.in_flight += 1;
        self.counters.popped.fetch_add(1, Ordering::Relaxed);
        Some((tenant, item))
    }

    /// Mark one popped item of `tenant` complete: releases an in-flight
    /// slot (possibly making its queued items eligible again) and wakes
    /// blocked producers/consumers.  Unknown tenants are a no-op —
    /// `finish` must never create gauge entries (tenant ids are
    /// client-controlled; see [`MAX_TRACKED_TENANTS`]).
    pub fn finish(&self, tenant: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(t) = inner.tenants.get_mut(tenant) {
            t.in_flight = t.in_flight.saturating_sub(1);
            t.finished += 1;
        }
        drop(inner);
        // A consumer may be parked on not_empty waiting for this
        // tenant's cap to release, and a producer on not_full for its
        // quota: wake both sides.
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Graceful drain (see [`JobQueue::close`]).  Idempotent.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Close **and discard** the backlog, returning the unprocessed
    /// `(tenant, item)` pairs in pop-priority order.  Idempotent.
    pub fn abort(&self) -> Vec<(String, T)> {
        let dropped: Vec<(String, T)> = {
            let mut inner = self.inner.lock().unwrap();
            inner.closed = true;
            let mut dropped = Vec::new();
            for class in inner.classes.iter_mut() {
                dropped.extend(class.drain(..));
            }
            for (tenant, _) in &dropped {
                if let Some(t) = inner.tenants.get_mut(tenant) {
                    t.queued = t.queued.saturating_sub(1);
                }
            }
            dropped
        };
        self.not_full.notify_all();
        self.not_empty.notify_all();
        dropped
    }

    /// Snapshot the global gauges (same shape as [`JobQueue::stats`]).
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            depth: self.len() as u64,
            high_water: self.counters.high_water.load(Ordering::Relaxed),
            producer_blocks: self.counters.producer_blocks.load(Ordering::Relaxed),
            pushed: self.counters.pushed.load(Ordering::Relaxed),
            popped: self.counters.popped.load(Ordering::Relaxed),
        }
    }

    /// Snapshot the per-tenant gauges, sorted by tenant id.
    pub fn tenant_stats(&self) -> Vec<(String, TenantStats)> {
        let inner = self.inner.lock().unwrap();
        inner
            .tenants
            .iter()
            .map(|(name, t)| {
                (
                    name.clone(),
                    TenantStats {
                        queued: t.queued as u64,
                        in_flight: t.in_flight as u64,
                        admitted: t.admitted,
                        quota_refusals: t.quota_refusals,
                        finished: t.finished,
                        shed: t.shed,
                    },
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn fifo_within_capacity() {
        let q = JobQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        let s = q.stats();
        assert_eq!(s.pushed, 4);
        assert_eq!(s.popped, 4);
        assert_eq!(s.high_water, 4);
        assert_eq!(s.producer_blocks, 0);
    }

    #[test]
    fn try_push_refuses_when_full_and_counts_blocks() {
        let q = JobQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Busy(3)) => {}
            other => panic!("expected Busy(3), got {other:?}"),
        }
        assert_eq!(q.stats().producer_blocks, 1);
        // Draining one item re-admits.
        assert_eq!(q.try_pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q = Arc::new(JobQueue::new(1));
        q.push(0usize).unwrap();
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 1..=20usize {
                    q.push(i).unwrap();
                }
            })
        };
        let mut seen = Vec::new();
        for _ in 0..=20 {
            seen.push(q.pop().unwrap());
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..=20).collect::<Vec<_>>());
        let s = q.stats();
        assert!(s.high_water <= 1, "depth bound violated: {}", s.high_water);
        assert!(s.producer_blocks > 0, "producer never blocked");
    }

    #[test]
    fn close_drains_then_signals_exhaustion() {
        let q = JobQueue::new(8);
        q.push('a').unwrap();
        q.push('b').unwrap();
        q.close();
        assert!(q.is_closed());
        assert!(q.push('c').is_err());
        assert!(matches!(q.try_push('c'), Err(PushError::Closed('c'))));
        assert_eq!(q.pop(), Some('a'));
        assert_eq!(q.pop(), Some('b'));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None); // idempotent exhaustion
    }

    #[test]
    fn abort_returns_the_backlog() {
        let q = JobQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.try_pop(), Some(0));
        let dropped = q.abort();
        assert_eq!(dropped, vec![1, 2, 3, 4]);
        assert_eq!(q.pop(), None);
        assert!(q.abort().is_empty());
    }

    #[test]
    fn close_unblocks_waiting_consumers() {
        let q = Arc::new(JobQueue::<u32>::new(2));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut n = 0usize;
                    while q.pop().is_some() {
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        for i in 0..10 {
            q.push(i).unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn try_pop_where_picks_matching_item() {
        let q = JobQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.try_pop_where(|&i| i % 2 == 1), Some(1));
        assert_eq!(q.try_pop_where(|&i| i % 2 == 1), Some(3));
        assert_eq!(q.try_pop_where(|&i| i > 10), None);
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
    }

    #[test]
    fn mpmc_under_contention_delivers_every_item_once() {
        let q = Arc::new(JobQueue::new(4));
        let delivered = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                let delivered = Arc::clone(&delivered);
                std::thread::spawn(move || {
                    while q.pop().is_some() {
                        delivered.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(delivered.load(Ordering::Relaxed), 200);
        let s = q.stats();
        assert_eq!(s.pushed, 200);
        assert_eq!(s.popped, 200);
        assert!(s.high_water <= 4);
    }

    // -----------------------------------------------------------------
    // TenantQueue: priority classes + per-tenant quotas.
    // -----------------------------------------------------------------

    #[test]
    fn priorities_pop_high_first_fifo_within_class() {
        let q = TenantQueue::new(16, TenantQuota::default());
        q.push("t", Priority::Low, "l1").unwrap();
        q.push("t", Priority::Normal, "n1").unwrap();
        q.push("t", Priority::High, "h1").unwrap();
        q.push("t", Priority::Normal, "n2").unwrap();
        q.push("t", Priority::High, "h2").unwrap();
        let order: Vec<&str> = (0..5).map(|_| q.pop().unwrap().1).collect();
        assert_eq!(order, vec!["h1", "h2", "n1", "n2", "l1"]);
        for _ in 0..5 {
            q.finish("t");
        }
        let ts = q.tenant_stats();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].0, "t");
        assert_eq!(ts[0].1.admitted, 5);
        assert_eq!(ts[0].1.finished, 5);
        assert_eq!(ts[0].1.queued, 0);
        assert_eq!(ts[0].1.in_flight, 0);
    }

    #[test]
    fn tenant_at_queued_cap_gets_at_quota_while_others_admit() {
        let quota = TenantQuota { max_queued: 2, max_in_flight: usize::MAX };
        let q = TenantQueue::new(16, quota);
        q.try_push("a", Priority::Normal, 1).unwrap();
        q.try_push("a", Priority::Normal, 2).unwrap();
        match q.try_push("a", Priority::Normal, 3) {
            Err(AdmitError::AtQuota(3)) => {}
            other => panic!("expected AtQuota(3), got {other:?}"),
        }
        // Another tenant is unaffected by a's quota.
        q.try_push("b", Priority::Normal, 10).unwrap();
        let ts = q.tenant_stats();
        assert_eq!(ts[0].0, "a");
        assert_eq!(ts[0].1.quota_refusals, 1);
        assert_eq!(ts[0].1.queued, 2);
        assert_eq!(ts[1].0, "b");
        assert_eq!(ts[1].1.queued, 1);
        // Draining one of a's items re-admits a.
        let (tenant, item) = q.pop().unwrap();
        assert_eq!((tenant.as_str(), item), ("a", 1));
        q.try_push("a", Priority::Normal, 3).unwrap();
        q.finish("a");
    }

    #[test]
    fn global_full_is_busy_not_at_quota() {
        let q = TenantQueue::new(2, TenantQuota::default());
        q.try_push("a", Priority::Normal, 1).unwrap();
        q.try_push("b", Priority::Normal, 2).unwrap();
        match q.try_push("c", Priority::Normal, 3) {
            Err(AdmitError::Busy(3)) => {}
            other => panic!("expected Busy(3), got {other:?}"),
        }
        assert_eq!(q.stats().producer_blocks, 1);
    }

    #[test]
    fn in_flight_cap_skips_tenant_but_not_others() {
        let quota = TenantQuota { max_queued: usize::MAX, max_in_flight: 1 };
        let q = TenantQueue::new(16, quota);
        q.push("a", Priority::High, "a1").unwrap();
        q.push("a", Priority::High, "a2").unwrap();
        q.push("b", Priority::Low, "b1").unwrap();
        // a1 pops (a now at in-flight cap); a2 is skipped even though
        // it outranks b1, so b1 flows past the capped tenant.
        assert_eq!(q.try_pop().unwrap(), ("a".to_string(), "a1"));
        assert_eq!(q.try_pop().unwrap(), ("b".to_string(), "b1"));
        assert!(q.try_pop().is_none(), "a is at its in-flight cap");
        assert_eq!(q.len(), 1);
        // Finishing a1 releases a2.
        q.finish("a");
        assert_eq!(q.try_pop().unwrap(), ("a".to_string(), "a2"));
        q.finish("a");
        q.finish("b");
    }

    #[test]
    fn finish_wakes_consumers_blocked_on_the_in_flight_cap() {
        let quota = TenantQuota { max_queued: usize::MAX, max_in_flight: 1 };
        let q = Arc::new(TenantQueue::new(16, quota));
        q.push("a", Priority::Normal, 1).unwrap();
        q.push("a", Priority::Normal, 2).unwrap();
        let (_, first) = q.pop().unwrap();
        assert_eq!(first, 1);
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                // Blocks: the only queued item belongs to a capped tenant.
                let got = q.pop();
                if let Some((tenant, _)) = &got {
                    q.finish(tenant);
                }
                got
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(50));
        q.finish("a");
        let got = waiter.join().unwrap();
        assert_eq!(got, Some(("a".to_string(), 2)));
    }

    #[test]
    fn blocking_push_waits_out_the_quota() {
        let quota = TenantQuota { max_queued: 1, max_in_flight: usize::MAX };
        let q = Arc::new(TenantQueue::new(16, quota));
        q.push("a", Priority::Normal, 0usize).unwrap();
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 1..=10usize {
                    q.push("a", Priority::Normal, i).unwrap();
                }
            })
        };
        let mut seen = Vec::new();
        for _ in 0..=10 {
            let (tenant, item) = q.pop().unwrap();
            seen.push(item);
            q.finish(&tenant);
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..=10).collect::<Vec<_>>());
        let ts = q.tenant_stats();
        assert!(ts[0].1.quota_refusals > 0, "producer never hit the quota");
    }

    #[test]
    fn tenant_close_drains_and_abort_returns_backlog() {
        let q = TenantQueue::new(16, TenantQuota::default());
        q.push("a", Priority::Normal, 1).unwrap();
        q.push("b", Priority::High, 2).unwrap();
        q.close();
        assert!(q.is_closed());
        assert!(q.push("a", Priority::Normal, 9).is_err());
        assert!(matches!(
            q.try_push("a", Priority::Normal, 9),
            Err(AdmitError::Closed(9))
        ));
        // Backlog still pops after close (graceful drain), high first.
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 1);
        assert!(q.pop().is_none());
        q.finish("a");
        q.finish("b");

        let q = TenantQueue::new(16, TenantQuota::default());
        q.push("a", Priority::Normal, 1).unwrap();
        q.push("b", Priority::High, 2).unwrap();
        let dropped = q.abort();
        assert_eq!(dropped.len(), 2);
        assert_eq!(dropped[0], ("b".to_string(), 2));
        assert_eq!(dropped[1], ("a".to_string(), 1));
        assert!(q.pop().is_none());
        assert!(q.abort().is_empty());
        for (_, t) in q.tenant_stats() {
            assert_eq!(t.queued, 0, "abort must zero the queued gauges");
        }
    }

    #[test]
    fn shed_limit_refuses_low_priority_but_admits_high() {
        // Deterministic: no consumer, so the backlog is exactly what
        // was pushed.  Depth 4, shed at 2 queued items.
        let q = TenantQueue::new_with_shed(4, TenantQuota::default(), 2);
        assert_eq!(q.shed_limit(), 2);
        q.try_push("a", Priority::Low, 1).unwrap();
        q.try_push("a", Priority::Normal, 2).unwrap();
        // At the shed limit: low-priority work is refused early...
        match q.try_push("a", Priority::Low, 3) {
            Err(AdmitError::Shed(3)) => {}
            other => panic!("expected Shed(3), got {other:?}"),
        }
        match q.try_push("b", Priority::Low, 4) {
            Err(AdmitError::Shed(4)) => {}
            other => panic!("expected Shed(4), got {other:?}"),
        }
        // ...while normal and high priority still admit up to depth.
        q.try_push("a", Priority::Normal, 5).unwrap();
        q.try_push("a", Priority::High, 6).unwrap();
        match q.try_push("a", Priority::High, 7) {
            Err(AdmitError::Busy(7)) => {}
            other => panic!("expected Busy(7) at full depth, got {other:?}"),
        }
        // Shed attribution: tenant "a" was tracked (it had queued
        // items) so its shed counts; "b" was brand new — no gauge
        // entry may be created for it.
        let ts = q.tenant_stats();
        assert_eq!(ts.len(), 1, "a shed refusal must not create tenant entries");
        assert_eq!(ts[0].0, "a");
        assert_eq!(ts[0].1.shed, 1);
        // Draining below the limit re-admits low-priority work.
        while q.try_pop().is_some() {
            q.finish("a");
        }
        q.try_push("a", Priority::Low, 8).unwrap();
    }

    #[test]
    fn zero_shed_limit_never_sheds() {
        let q = TenantQueue::new(2, TenantQuota::default());
        q.try_push("a", Priority::Low, 1).unwrap();
        q.try_push("a", Priority::Low, 2).unwrap();
        // Full queue is Busy, not Shed, when shedding is off.
        assert!(matches!(q.try_push("a", Priority::Low, 3), Err(AdmitError::Busy(3))));
    }

    #[test]
    fn tenant_try_pop_where_respects_caps_and_priority() {
        let quota = TenantQuota { max_queued: usize::MAX, max_in_flight: 1 };
        let q = TenantQueue::new(16, quota);
        q.push("a", Priority::Normal, 10).unwrap();
        q.push("b", Priority::Normal, 11).unwrap();
        q.push("b", Priority::High, 12).unwrap();
        // b's high-priority even item wins over a's earlier normal one.
        assert_eq!(q.try_pop_where(|&i| i % 2 == 0), Some(("b".to_string(), 12)));
        // b is now at its in-flight cap: its remaining odd item is
        // skipped, and a has no odd item... 11 is odd but capped, 10 is
        // even. So no odd match is eligible.
        assert_eq!(q.try_pop_where(|&i| i % 2 == 1), None);
        q.finish("b");
        assert_eq!(q.try_pop_where(|&i| i % 2 == 1), Some(("b".to_string(), 11)));
        q.finish("b");
        q.finish("a"); // no-op resilience: a never popped
    }
}
