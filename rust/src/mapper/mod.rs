//! Minimizer-based read mapper (minimap2 substitute).
//!
//! Apollo's pipeline needs read-to-assembly mappings (the paper uses
//! minimap2).  This is a compact reimplementation of the same idea:
//! index the (w, k)-minimizers of the reference, look up each read's
//! minimizers, and vote on the alignment diagonal (ref_pos − read_pos).
//! The winning diagonal places the read; chaining/extension is
//! unnecessary because the pHMM training step absorbs local indels.

use std::collections::HashMap;

use crate::seq::Sequence;

/// Mapper configuration.
#[derive(Clone, Copy, Debug)]
pub struct MapperConfig {
    /// k-mer size (DNA default 11 → 4^11 ≈ 4M keys).
    pub k: usize,
    /// Minimizer window (take the minimum hash of every `w` k-mers).
    pub w: usize,
    /// Minimum minimizer hits to accept a mapping.
    pub min_hits: usize,
    /// Diagonal bucket width (tolerates indel drift).
    pub band: usize,
}

impl Default for MapperConfig {
    fn default() -> Self {
        MapperConfig { k: 11, w: 5, min_hits: 4, band: 64 }
    }
}

/// A read placement on the reference.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mapping {
    /// Inferred start of the read on the reference.
    pub ref_start: usize,
    /// Inferred end (exclusive).
    pub ref_end: usize,
    /// Number of supporting minimizer hits.
    pub hits: usize,
    /// Supporting anchors `(read_pos, ref_pos)`, ascending in `ref_pos`.
    /// Long noisy reads drift (indels change the read/reference pacing),
    /// so consumers lift reference coordinates into read coordinates
    /// through the nearest anchor instead of assuming linearity.
    pub anchors: Vec<(u32, u32)>,
}

impl Mapping {
    /// Read coordinate corresponding to reference position `ref_pos`,
    /// lifted through the nearest anchor at or before it (falls back to
    /// the first anchor, then to the global diagonal).
    pub fn lift_to_read(&self, ref_pos: usize) -> usize {
        let mut best: Option<(u32, u32)> = None;
        for &(rp, gp) in &self.anchors {
            if gp as usize <= ref_pos {
                best = Some((rp, gp));
            } else {
                break;
            }
        }
        let (rp, gp) = best.or_else(|| self.anchors.first().copied()).unwrap_or((0, 0));
        (rp as i64 + ref_pos as i64 - gp as i64).max(0) as usize
    }
}

/// Minimizer index over one reference sequence.
pub struct MinimizerIndex {
    cfg: MapperConfig,
    ref_len: usize,
    /// minimizer hash → reference positions.
    table: HashMap<u64, Vec<u32>>,
}

/// 64-bit mix (splitmix64 finalizer) — k-mer hash.
#[inline]
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Longest subsequence of `(read_pos, ref_pos)` anchors (already sorted
/// by `ref_pos`) with strictly increasing `read_pos` — O(n log n)
/// patience chaining.
fn longest_increasing_chain(anchors: &[(u32, u32)]) -> Vec<(u32, u32)> {
    if anchors.is_empty() {
        return Vec::new();
    }
    // tails[k] = index of the smallest read_pos ending a chain of len k+1.
    let mut tails: Vec<usize> = Vec::new();
    let mut back: Vec<isize> = vec![-1; anchors.len()];
    for (i, &(rp, _)) in anchors.iter().enumerate() {
        let pos = tails.partition_point(|&j| anchors[j].0 < rp);
        if pos > 0 {
            back[i] = tails[pos - 1] as isize;
        }
        if pos == tails.len() {
            tails.push(i);
        } else {
            tails[pos] = i;
        }
    }
    let mut chain = Vec::with_capacity(tails.len());
    let mut cur = *tails.last().unwrap() as isize;
    while cur >= 0 {
        chain.push(anchors[cur as usize]);
        cur = back[cur as usize];
    }
    chain.reverse();
    chain
}

/// Rolling 2-bit pack of DNA k-mers; returns (position, hash) minimizers.
fn minimizers(seq: &[u8], k: usize, w: usize) -> Vec<(u32, u64)> {
    if seq.len() < k {
        return Vec::new();
    }
    let n_kmers = seq.len() - k + 1;
    let mut hashes = Vec::with_capacity(n_kmers);
    let mask = if 2 * k >= 64 { u64::MAX } else { (1u64 << (2 * k)) - 1 };
    let mut acc = 0u64;
    for (i, &b) in seq.iter().enumerate() {
        acc = ((acc << 2) | b as u64) & mask;
        if i + 1 >= k {
            hashes.push(mix(acc));
        }
    }
    // Window minima with deduplication of consecutive repeats.
    let mut out: Vec<(u32, u64)> = Vec::new();
    for win_start in 0..n_kmers.saturating_sub(w - 1) {
        let mut best = (win_start, hashes[win_start]);
        for j in win_start + 1..win_start + w {
            if hashes[j] < best.1 {
                best = (j, hashes[j]);
            }
        }
        if out.last().map(|&(p, _)| p as usize) != Some(best.0) {
            out.push((best.0 as u32, best.1));
        }
    }
    out
}

impl MinimizerIndex {
    /// Build the index of a reference sequence.
    pub fn build(reference: &Sequence, cfg: MapperConfig) -> Self {
        let mut table: HashMap<u64, Vec<u32>> = HashMap::new();
        for (pos, h) in minimizers(&reference.data, cfg.k, cfg.w) {
            table.entry(h).or_default().push(pos);
        }
        // Mask over-represented minimizers (repeats) like minimap2 -f.
        let cap = 64;
        table.retain(|_, v| v.len() <= cap);
        MinimizerIndex { cfg, ref_len: reference.len(), table }
    }

    /// Number of indexed minimizers.
    pub fn n_minimizers(&self) -> usize {
        self.table.values().map(|v| v.len()).sum()
    }

    /// Map one read by diagonal voting; the placement is refined to the
    /// median raw diagonal of the winning bucket (bucket quantization
    /// alone would misplace reads by up to `band-1` bases, which would
    /// poison the downstream pHMM training).
    pub fn map(&self, read: &Sequence) -> Option<Mapping> {
        let mut votes: HashMap<i64, usize> = HashMap::new();
        let mut hits: Vec<(u32, u32, i64)> = Vec::new(); // (read, ref, diff)
        let band = self.cfg.band as i64;
        for (rpos, h) in minimizers(&read.data, self.cfg.k, self.cfg.w) {
            if let Some(ref_positions) = self.table.get(&h) {
                for &gpos in ref_positions {
                    let diff = gpos as i64 - rpos as i64;
                    hits.push((rpos, gpos, diff));
                    *votes.entry(diff.div_euclid(band)).or_insert(0) += 1;
                }
            }
        }
        // Merge adjacent diagonal buckets (indel drift across the edge).
        // Long reads drift beyond one band, so widen the acceptance
        // window proportionally to the read length (~10% indel drift).
        let drift_bands = 1 + (read.len() as i64 / 10) / band;
        let (&best_diag, _) = votes.iter().max_by_key(|&(_, &c)| c)?;
        let mut anchors: Vec<(u32, u32)> = hits
            .into_iter()
            .filter(|&(_, _, d)| (d.div_euclid(band) - best_diag).abs() <= drift_bands)
            .map(|(rp, gp, _)| (rp, gp))
            .collect();
        if anchors.len() < self.cfg.min_hits {
            return None;
        }
        anchors.sort_unstable_by_key(|&(_, gp)| gp);
        // Chain: keep the longest read-order-monotone subsequence (LIS
        // over read positions).  Spurious hits — k-mer collisions or
        // repeat copies inside the widened diagonal window — violate
        // monotonicity and fall out; a greedy scan would instead let one
        // false anchor shadow a run of true ones.
        let anchors = longest_increasing_chain(&anchors);
        if anchors.len() < self.cfg.min_hits {
            return None;
        }
        let (rp0, gp0) = anchors[0];
        let start = (gp0 as i64 - rp0 as i64).max(0) as usize;
        let end = (start + read.len()).min(self.ref_len);
        if start >= end {
            return None;
        }
        Some(Mapping {
            ref_start: start.min(self.ref_len - 1),
            ref_end: end,
            hits: anchors.len(),
            anchors,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{generate_genome, simulate_read, ErrorProfile, XorShift};

    #[test]
    fn maps_exact_reads_precisely() {
        let mut rng = XorShift::new(1);
        let genome = generate_genome(&mut rng, 20_000);
        let index = MinimizerIndex::build(&genome, MapperConfig::default());
        for i in 0..20 {
            let start = 500 + i * 700;
            let read = simulate_read(&mut rng, &genome, start, 800, &ErrorProfile::perfect(), i);
            let m = index.map(&read.seq).expect("exact read must map");
            assert!(
                (m.ref_start as i64 - start as i64).abs() <= 64,
                "start {start} mapped to {}",
                m.ref_start
            );
        }
    }

    #[test]
    fn maps_noisy_pacbio_reads() {
        let mut rng = XorShift::new(2);
        let genome = generate_genome(&mut rng, 50_000);
        let index = MinimizerIndex::build(&genome, MapperConfig::default());
        let mut mapped = 0;
        let mut correct = 0;
        for i in 0..50 {
            let start = rng.below(45_000);
            let read = simulate_read(&mut rng, &genome, start, 2000, &ErrorProfile::pacbio(), i);
            if let Some(m) = index.map(&read.seq) {
                mapped += 1;
                if (m.ref_start as i64 - start as i64).abs() <= 256 {
                    correct += 1;
                }
            }
        }
        assert!(mapped >= 40, "only {mapped}/50 mapped");
        assert!(correct as f64 >= mapped as f64 * 0.9, "{correct}/{mapped} correct");
    }

    #[test]
    fn random_reads_do_not_map() {
        let mut rng = XorShift::new(3);
        let genome = generate_genome(&mut rng, 20_000);
        let index = MinimizerIndex::build(&genome, MapperConfig::default());
        let mut false_hits = 0;
        for _ in 0..20 {
            let junk = Sequence::from_symbols(
                "junk",
                crate::testutil::random_seq(&mut rng, 1000, 4),
            );
            if index.map(&junk).is_some() {
                false_hits += 1;
            }
        }
        assert!(false_hits <= 2, "false hits: {false_hits}");
    }

    #[test]
    fn short_reads_rejected() {
        let mut rng = XorShift::new(4);
        let genome = generate_genome(&mut rng, 5000);
        let index = MinimizerIndex::build(&genome, MapperConfig::default());
        let tiny = Sequence::from_symbols("t", vec![0, 1, 2]);
        assert!(index.map(&tiny).is_none());
    }

    #[test]
    fn minimizer_positions_are_sorted_and_dense() {
        let mut rng = XorShift::new(5);
        let genome = generate_genome(&mut rng, 10_000);
        let mins = minimizers(&genome.data, 11, 5);
        assert!(mins.len() > 1000);
        for w in mins.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
    }
}
