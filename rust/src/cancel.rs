//! Cooperative cancellation: [`CancelToken`].
//!
//! A request may carry a deadline and/or be cancelled explicitly; the
//! compute path polls the token at coarse boundaries (queue pop, the
//! per-read loop of the E-step, the per-profile loop of Search) and
//! aborts the *whole request* with a typed
//! [`ApHmmError::Cancelled`](crate::ApHmmError::Cancelled) when it
//! fires.  Checks never perturb sums: a request either completes
//! bit-identically to an uncancelled run or returns no result at all.
//!
//! The default token ([`CancelToken::none`]) holds no allocation and
//! its `check` is a single `Option` test, so paths that never cancel
//! pay nothing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::error::CancelCause;

struct CancelInner {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
}

/// A cloneable cancellation handle shared between a request's
/// submitter and the worker computing it.  See the module docs.
#[derive(Clone, Default)]
pub struct CancelToken {
    inner: Option<Arc<CancelInner>>,
}

impl CancelToken {
    /// A token that never fires (no allocation).
    pub fn none() -> CancelToken {
        CancelToken { inner: None }
    }

    /// A cancellable token, expiring at `deadline` if one is given.
    pub fn with_deadline(deadline: Option<Instant>) -> CancelToken {
        CancelToken {
            inner: Some(Arc::new(CancelInner {
                cancelled: AtomicBool::new(false),
                deadline,
            })),
        }
    }

    /// Request cancellation.  Idempotent; a no-op on [`none`] tokens.
    ///
    /// [`none`]: CancelToken::none
    pub fn cancel(&self) {
        if let Some(inner) = &self.inner {
            inner.cancelled.store(true, Ordering::Release);
        }
    }

    /// Why this token has fired, if it has.  Explicit cancellation
    /// wins over a deadline when both hold (the caller asked first).
    pub fn check(&self) -> Option<CancelCause> {
        let inner = self.inner.as_ref()?;
        if inner.cancelled.load(Ordering::Acquire) {
            return Some(CancelCause::Cancelled);
        }
        match inner.deadline {
            Some(d) if Instant::now() >= d => Some(CancelCause::DeadlineExceeded),
            _ => None,
        }
    }

    /// The deadline this token carries, if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.inner.as_ref().and_then(|i| i.deadline)
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            None => write!(f, "CancelToken::none"),
            Some(inner) => f
                .debug_struct("CancelToken")
                .field("cancelled", &inner.cancelled.load(Ordering::Relaxed))
                .field("deadline", &inner.deadline)
                .finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn none_token_never_fires() {
        let t = CancelToken::none();
        t.cancel();
        assert!(t.check().is_none());
        assert!(t.deadline().is_none());
    }

    #[test]
    fn explicit_cancel_fires_and_wins_over_deadline() {
        let t = CancelToken::with_deadline(None);
        assert!(t.check().is_none());
        t.cancel();
        assert_eq!(t.check(), Some(CancelCause::Cancelled));

        // Both expired deadline and explicit cancel: cancel wins.
        let t = CancelToken::with_deadline(Some(Instant::now() - Duration::from_millis(1)));
        assert_eq!(t.check(), Some(CancelCause::DeadlineExceeded));
        t.cancel();
        assert_eq!(t.check(), Some(CancelCause::Cancelled));
    }

    #[test]
    fn clones_share_state() {
        let t = CancelToken::with_deadline(None);
        let c = t.clone();
        c.cancel();
        assert_eq!(t.check(), Some(CancelCause::Cancelled));
    }
}
