//! Coordinator/server metrics: throughput, latency distribution, queue
//! backpressure gauges.
//!
//! One [`Metrics`] instance is shared (lock-free) by every worker of a
//! coordinator run or a [`crate::server::Server`] lifetime.  Latencies
//! feed a fixed-bucket power-of-two histogram, so [`MetricsSummary`]
//! reports p50/p99 instead of only sum/max; queue gauges mirror the
//! most recently absorbed [`crate::server::JobQueue`] snapshot, so the
//! summary shows whether `queue_depth` actually exerted backpressure.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::server::queue::MAX_TRACKED_TENANTS;

/// Latency histogram buckets: bucket `i` holds latencies in
/// `[2^(i-1), 2^i)` ns (bucket 0 holds 0 ns; the last bucket holds
/// everything ≥ 2^(N-2) ns, ≈ 4.6 min).  Fixed buckets keep recording
/// a single atomic increment.
const LATENCY_BUCKETS: usize = 39;

/// Why a request failed, for the by-cause failure counters.  Wire
/// names (`name()`) appear in the `stats` / `tenants` commands and in
/// [`crate::server::ResponseBody::Failure`] lines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureCause {
    /// The request's deadline passed before it completed.
    DeadlineExceeded,
    /// The submitter cancelled the request.
    Cancelled,
    /// The job panicked and was contained at the per-job boundary.
    Panicked,
    /// Load shedding refused the request at admission.
    Shed,
}

impl FailureCause {
    /// Stable snake_case wire name.
    pub fn name(&self) -> &'static str {
        match self {
            FailureCause::DeadlineExceeded => "deadline_exceeded",
            FailureCause::Cancelled => "cancelled",
            FailureCause::Panicked => "panicked",
            FailureCause::Shed => "shed",
        }
    }
}

/// Shared (lock-free) counters updated by workers.
#[derive(Debug)]
pub struct Metrics {
    /// Jobs completed.
    pub jobs_done: AtomicU64,
    /// Jobs that failed (numerically dead chunks etc.).
    pub jobs_failed: AtomicU64,
    /// Total Baum-Welch timesteps processed.
    pub timesteps: AtomicU64,
    /// Total states processed.
    pub states: AtomicU64,
    /// Sum of per-job latencies (ns).
    pub latency_sum_ns: AtomicU64,
    /// Max per-job latency (ns).
    pub latency_max_ns: AtomicU64,
    /// Reads skipped during training (empty or numerically dead) —
    /// surfaced so dropped coverage is visible instead of silent.
    pub reads_skipped: AtomicU64,
    /// Current job-queue depth (gauge; latest absorbed snapshot).
    pub queue_depth: AtomicU64,
    /// Highest job-queue depth observed (monotone across absorbs).
    pub queue_high_water: AtomicU64,
    /// Producer admissions refused/blocked by the full queue (latest
    /// absorbed snapshot — monotone within one queue's lifetime).
    pub producer_blocks: AtomicU64,
    /// Failures whose deadline expired (subset of `jobs_failed`).
    pub failures_deadline_exceeded: AtomicU64,
    /// Failures cancelled by the submitter (subset of `jobs_failed`).
    pub failures_cancelled: AtomicU64,
    /// Jobs that panicked and were contained at the per-job boundary
    /// (subset of `jobs_failed`; surfaced as `pool_panics`).
    pub failures_panicked: AtomicU64,
    /// Requests refused by load shedding at admission (never admitted,
    /// so *not* counted in `jobs_failed`).
    pub failures_shed: AtomicU64,
    /// Power-of-two latency histogram (see [`LATENCY_BUCKETS`]).
    latency_hist: [AtomicU64; LATENCY_BUCKETS],
    /// Per-tenant gauges (multi-tenant serving; empty for coordinator
    /// runs).  BTreeMap keeps snapshot order deterministic.
    tenants: Mutex<BTreeMap<String, TenantGauges>>,
}

/// Per-tenant counter block inside [`Metrics`].  Completion counts are
/// recorded by workers; the admission-side gauges mirror the latest
/// absorbed [`crate::server::TenantStats`] snapshot.
#[derive(Clone, Copy, Debug, Default)]
struct TenantGauges {
    admitted: u64,
    completed: u64,
    failed: u64,
    quota_refusals: u64,
    queued: u64,
    in_flight: u64,
    deadline_exceeded: u64,
    cancelled: u64,
    panicked: u64,
    /// Mirrors the queue's admission-side shed counter (absorbed, not
    /// worker-recorded — shed requests never reach a worker).
    shed: u64,
}

// Tenant-map bounding (tenant ids are client-controlled and must not
// grow the map, or every summary, without limit): the accurate
// eviction runs in [`Metrics::evict_stale_tenants`], fed the queue's
// *current* tenant set by the server right after it absorbed fresh
// gauges — the mirrored gauges alone can be stale and must not decide
// evictions, or a tenant with real queued work could lose its
// counters.  `record_tenant_done` only refuses to create brand-new
// entries past a generous overflow bound (attribution for overflow
// tenants is dropped, live entries are never evicted there).

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            jobs_done: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            timesteps: AtomicU64::new(0),
            states: AtomicU64::new(0),
            latency_sum_ns: AtomicU64::new(0),
            latency_max_ns: AtomicU64::new(0),
            reads_skipped: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            queue_high_water: AtomicU64::new(0),
            producer_blocks: AtomicU64::new(0),
            failures_deadline_exceeded: AtomicU64::new(0),
            failures_cancelled: AtomicU64::new(0),
            failures_panicked: AtomicU64::new(0),
            failures_shed: AtomicU64::new(0),
            latency_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            tenants: Mutex::new(BTreeMap::new()),
        }
    }
}

/// Histogram bucket of a latency: 0 ns → 0, else `floor(log2) + 1`,
/// clamped to the last (overflow) bucket.
fn bucket_of(latency_ns: u64) -> usize {
    ((64 - latency_ns.leading_zeros()) as usize).min(LATENCY_BUCKETS - 1)
}

/// Upper bound (ns) of histogram bucket `i`.
fn bucket_bound_ns(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << i
    }
}

impl Metrics {
    /// Record one finished job.
    pub fn record(&self, latency_ns: u64, timesteps: u64, states: u64) {
        self.jobs_done.fetch_add(1, Ordering::Relaxed);
        self.timesteps.fetch_add(timesteps, Ordering::Relaxed);
        self.states.fetch_add(states, Ordering::Relaxed);
        self.latency_sum_ns.fetch_add(latency_ns, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(latency_ns, Ordering::Relaxed);
        self.latency_hist[bucket_of(latency_ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a failed job.
    pub fn record_failure(&self) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a failed request *with* its latency and cause: failures
    /// feed the latency histogram too (a fleet whose p99 is dominated
    /// by requests that die at their deadline must show it), and the
    /// cause increments its by-cause counter.  `cause = None` is a
    /// plain execution error.
    pub fn record_failed_request(&self, latency_ns: u64, cause: Option<FailureCause>) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        self.latency_sum_ns.fetch_add(latency_ns, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(latency_ns, Ordering::Relaxed);
        self.latency_hist[bucket_of(latency_ns)].fetch_add(1, Ordering::Relaxed);
        match cause {
            Some(FailureCause::DeadlineExceeded) => {
                self.failures_deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            }
            Some(FailureCause::Cancelled) => {
                self.failures_cancelled.fetch_add(1, Ordering::Relaxed);
            }
            Some(FailureCause::Panicked) => {
                self.failures_panicked.fetch_add(1, Ordering::Relaxed);
            }
            Some(FailureCause::Shed) => {
                self.failures_shed.fetch_add(1, Ordering::Relaxed);
            }
            None => {}
        }
    }

    /// Record a request refused by load shedding (admission-side: the
    /// request was never a job, so `jobs_failed` is untouched).
    pub fn record_shed(&self) {
        self.failures_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record reads skipped while training a job.
    pub fn record_skipped_reads(&self, n: u64) {
        self.reads_skipped.fetch_add(n, Ordering::Relaxed);
    }

    /// Fold a job-queue gauge snapshot in: `depth` and `blocks` mirror
    /// the snapshot (idempotent for one queue), `high_water` is kept
    /// monotone so repeated absorbs never lose the peak.
    pub fn absorb_queue(&self, depth: u64, high_water: u64, blocks: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_high_water.fetch_max(high_water, Ordering::Relaxed);
        self.producer_blocks.store(blocks, Ordering::Relaxed);
    }

    /// Record a completed (or failed) request for `tenant`.  Past the
    /// overflow bound, completions of brand-new tenants go unattributed
    /// (the aggregate counters still see them) rather than evicting a
    /// live entry on possibly-stale gauges.
    pub fn record_tenant_done(&self, tenant: &str, ok: bool) {
        let mut tenants = self.tenants.lock().unwrap();
        if !tenants.contains_key(tenant) && tenants.len() >= MAX_TRACKED_TENANTS * 4 {
            return;
        }
        let t = tenants.entry(tenant.to_string()).or_default();
        if ok {
            t.completed += 1;
        } else {
            t.failed += 1;
        }
    }

    /// Record a failed request for `tenant` with its cause (same
    /// overflow bound as [`record_tenant_done`]).  Increments both the
    /// tenant's `failed` total and the by-cause counter.
    ///
    /// [`record_tenant_done`]: Metrics::record_tenant_done
    pub fn record_tenant_failure(&self, tenant: &str, cause: Option<FailureCause>) {
        let mut tenants = self.tenants.lock().unwrap();
        if !tenants.contains_key(tenant) && tenants.len() >= MAX_TRACKED_TENANTS * 4 {
            return;
        }
        let t = tenants.entry(tenant.to_string()).or_default();
        t.failed += 1;
        match cause {
            Some(FailureCause::DeadlineExceeded) => t.deadline_exceeded += 1,
            Some(FailureCause::Cancelled) => t.cancelled += 1,
            Some(FailureCause::Panicked) => t.panicked += 1,
            Some(FailureCause::Shed) => t.shed += 1,
            None => {}
        }
    }

    /// Fold one tenant's admission-side gauge snapshot in (idempotent
    /// for one queue — the counters mirror the snapshot).
    pub fn absorb_tenant(
        &self,
        tenant: &str,
        admitted: u64,
        quota_refusals: u64,
        queued: u64,
        in_flight: u64,
        shed: u64,
    ) {
        let mut tenants = self.tenants.lock().unwrap();
        let t = tenants.entry(tenant.to_string()).or_default();
        t.admitted = admitted;
        t.quota_refusals = quota_refusals;
        t.queued = queued;
        t.in_flight = in_flight;
        t.shed = shed;
    }

    /// Reconcile the tenant map against `active` — the queue's
    /// *current* tenant set, whose just-absorbed gauges are
    /// authoritative — then bound it past [`MAX_TRACKED_TENANTS`].  A
    /// tenant absent from `active` has nothing queued or in flight
    /// (the queue evicts only idle entries, so absence means idle):
    /// its mirrored gauges are cleared first, so a stale snapshot
    /// taken while it was busy can neither pin the entry here forever
    /// nor report phantom queued work.  A tenant with real work is in
    /// `active` and can never be evicted.
    pub fn evict_stale_tenants(&self, active: &[&str]) {
        let active: std::collections::HashSet<&str> = active.iter().copied().collect();
        let mut tenants = self.tenants.lock().unwrap();
        for (name, t) in tenants.iter_mut() {
            if !active.contains(name.as_str()) {
                t.queued = 0;
                t.in_flight = 0;
            }
        }
        if tenants.len() > MAX_TRACKED_TENANTS {
            tenants.retain(|name, t| {
                t.queued > 0 || t.in_flight > 0 || active.contains(name.as_str())
            });
        }
    }

    /// Latency quantile from the histogram: the upper bound of the
    /// first bucket whose cumulative count reaches `q` of all recorded
    /// jobs (0 when nothing was recorded).
    fn latency_quantile_ms(&self, q: f64) -> f64 {
        let counts: Vec<u64> =
            self.latency_hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_bound_ns(i) as f64 / 1e6;
            }
        }
        bucket_bound_ns(LATENCY_BUCKETS - 1) as f64 / 1e6
    }

    /// Snapshot as a display-friendly summary.
    pub fn summary(&self, wall_seconds: f64) -> MetricsSummary {
        let done = self.jobs_done.load(Ordering::Relaxed);
        let sum = self.latency_sum_ns.load(Ordering::Relaxed);
        let tenants = self
            .tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(name, t)| TenantSummary {
                tenant: name.clone(),
                admitted: t.admitted,
                completed: t.completed,
                failed: t.failed,
                quota_refusals: t.quota_refusals,
                queued: t.queued,
                in_flight: t.in_flight,
                deadline_exceeded: t.deadline_exceeded,
                cancelled: t.cancelled,
                panicked: t.panicked,
                shed: t.shed,
            })
            .collect();
        MetricsSummary {
            tenants,
            jobs_done: done,
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            timesteps: self.timesteps.load(Ordering::Relaxed),
            states: self.states.load(Ordering::Relaxed),
            reads_skipped: self.reads_skipped.load(Ordering::Relaxed),
            mean_latency_ms: if done > 0 { sum as f64 / done as f64 / 1e6 } else { 0.0 },
            max_latency_ms: self.latency_max_ns.load(Ordering::Relaxed) as f64 / 1e6,
            latency_p50_ms: self.latency_quantile_ms(0.50),
            latency_p99_ms: self.latency_quantile_ms(0.99),
            jobs_per_second: if wall_seconds > 0.0 { done as f64 / wall_seconds } else { 0.0 },
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            queue_high_water: self.queue_high_water.load(Ordering::Relaxed),
            producer_blocks: self.producer_blocks.load(Ordering::Relaxed),
            deadline_exceeded: self.failures_deadline_exceeded.load(Ordering::Relaxed),
            cancelled: self.failures_cancelled.load(Ordering::Relaxed),
            pool_panics: self.failures_panicked.load(Ordering::Relaxed),
            shed: self.failures_shed.load(Ordering::Relaxed),
        }
    }
}

/// One tenant's slice of a [`MetricsSummary`].
#[derive(Clone, Debug, Default)]
pub struct TenantSummary {
    /// Tenant id.
    pub tenant: String,
    /// Requests admitted to the queue.
    pub admitted: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that answered an error.
    pub failed: u64,
    /// Admissions refused/blocked by a tenant quota cap.
    pub quota_refusals: u64,
    /// Requests currently queued (gauge).
    pub queued: u64,
    /// Requests currently in flight (gauge).
    pub in_flight: u64,
    /// Failures whose deadline expired.
    pub deadline_exceeded: u64,
    /// Failures cancelled by the submitter.
    pub cancelled: u64,
    /// Failures contained from a panicking job.
    pub panicked: u64,
    /// Admissions refused by load shedding.
    pub shed: u64,
}

/// Snapshot of the metrics.
#[derive(Clone, Debug)]
pub struct MetricsSummary {
    /// Jobs completed.
    pub jobs_done: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Baum-Welch timesteps processed.
    pub timesteps: u64,
    /// States processed.
    pub states: u64,
    /// Reads skipped during training across all jobs.
    pub reads_skipped: u64,
    /// Mean job latency (ms).
    pub mean_latency_ms: f64,
    /// Max job latency (ms).
    pub max_latency_ms: f64,
    /// Median job latency (ms, histogram bucket upper bound).
    pub latency_p50_ms: f64,
    /// 99th-percentile job latency (ms, histogram bucket upper bound).
    pub latency_p99_ms: f64,
    /// Throughput (jobs/s).
    pub jobs_per_second: f64,
    /// Job-queue depth at the last absorbed snapshot.
    pub queue_depth: u64,
    /// Highest job-queue depth observed.
    pub queue_high_water: u64,
    /// Producer admissions refused/blocked by a full queue.
    pub producer_blocks: u64,
    /// Failures whose deadline expired (subset of `jobs_failed`).
    pub deadline_exceeded: u64,
    /// Failures cancelled by the submitter (subset of `jobs_failed`).
    pub cancelled: u64,
    /// Jobs that panicked and were contained at the per-job boundary.
    pub pool_panics: u64,
    /// Requests refused by load shedding at admission.
    pub shed: u64,
    /// Per-tenant gauges, sorted by tenant id (empty for coordinator
    /// runs — only the serving layer is multi-tenant).
    pub tenants: Vec<TenantSummary>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let m = Metrics::default();
        m.record(1_000_000, 100, 5000);
        m.record(3_000_000, 200, 9000);
        m.record_failure();
        m.record_skipped_reads(3);
        let s = m.summary(2.0);
        assert_eq!(s.jobs_done, 2);
        assert_eq!(s.jobs_failed, 1);
        assert_eq!(s.timesteps, 300);
        assert_eq!(s.reads_skipped, 3);
        assert!((s.mean_latency_ms - 2.0).abs() < 1e-9);
        assert!((s.max_latency_ms - 3.0).abs() < 1e-9);
        assert!((s.jobs_per_second - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_bracket_the_latencies() {
        let m = Metrics::default();
        // 99 fast jobs (~1 ms) and one slow job (~1 s).
        for _ in 0..99 {
            m.record(1_000_000, 1, 1);
        }
        m.record(1_000_000_000, 1, 1);
        let s = m.summary(1.0);
        // p50 lands in the ~1 ms bucket (bound within 2x), p99 must not
        // be dragged up to the outlier, and the max still sees it.
        assert!(s.latency_p50_ms >= 1.0 && s.latency_p50_ms <= 3.0, "p50 {}", s.latency_p50_ms);
        assert!(s.latency_p99_ms <= 3.0, "p99 {}", s.latency_p99_ms);
        assert!((s.max_latency_ms - 1000.0).abs() < 1e-9);
        // With the outlier weighted at 2%+, p99 climbs into its bucket.
        m.record(1_000_000_000, 1, 1);
        m.record(1_000_000_000, 1, 1);
        let s = m.summary(1.0);
        assert!(s.latency_p99_ms >= 500.0, "p99 {}", s.latency_p99_ms);
    }

    #[test]
    fn zero_jobs_have_zero_quantiles() {
        let m = Metrics::default();
        let s = m.summary(1.0);
        assert_eq!(s.latency_p50_ms, 0.0);
        assert_eq!(s.latency_p99_ms, 0.0);
    }

    #[test]
    fn tenant_gauges_fold_into_the_summary_sorted() {
        let m = Metrics::default();
        m.record_tenant_done("bravo", true);
        m.record_tenant_done("bravo", false);
        m.record_tenant_done("alpha", true);
        m.absorb_tenant("bravo", 5, 2, 1, 1, 0);
        m.absorb_tenant("alpha", 3, 0, 0, 1, 0);
        // Absorb is idempotent: a second snapshot mirrors, not adds.
        m.absorb_tenant("alpha", 4, 0, 0, 0, 2);
        let s = m.summary(1.0);
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[0].tenant, "alpha");
        assert_eq!(s.tenants[0].admitted, 4);
        assert_eq!(s.tenants[0].completed, 1);
        assert_eq!(s.tenants[0].in_flight, 0);
        assert_eq!(s.tenants[0].shed, 2);
        assert_eq!(s.tenants[1].tenant, "bravo");
        assert_eq!(s.tenants[1].admitted, 5);
        assert_eq!(s.tenants[1].completed, 1);
        assert_eq!(s.tenants[1].failed, 1);
        assert_eq!(s.tenants[1].quota_refusals, 2);
    }

    #[test]
    fn failures_count_by_cause_and_feed_the_histogram() {
        let m = Metrics::default();
        // Only failed requests are recorded; the histogram must still
        // see their latencies (p50 > 0 proves it — an empty histogram
        // reports exactly 0).
        m.record_failed_request(2_000_000, Some(FailureCause::DeadlineExceeded));
        m.record_failed_request(2_000_000, Some(FailureCause::Cancelled));
        m.record_failed_request(2_000_000, Some(FailureCause::Panicked));
        m.record_failed_request(2_000_000, None);
        m.record_shed();
        let s = m.summary(1.0);
        assert_eq!(s.jobs_done, 0);
        assert_eq!(s.jobs_failed, 4, "shed is admission-side, not a failed job");
        assert_eq!(s.deadline_exceeded, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.pool_panics, 1);
        assert_eq!(s.shed, 1);
        assert!(s.latency_p50_ms > 0.0, "failed requests must land in the histogram");
        assert!((s.max_latency_ms - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tenant_failures_count_by_cause() {
        let m = Metrics::default();
        m.record_tenant_failure("acme", Some(FailureCause::DeadlineExceeded));
        m.record_tenant_failure("acme", Some(FailureCause::Cancelled));
        m.record_tenant_failure("acme", Some(FailureCause::Panicked));
        m.record_tenant_failure("acme", None);
        let s = m.summary(1.0);
        assert_eq!(s.tenants.len(), 1);
        assert_eq!(s.tenants[0].failed, 4);
        assert_eq!(s.tenants[0].deadline_exceeded, 1);
        assert_eq!(s.tenants[0].cancelled, 1);
        assert_eq!(s.tenants[0].panicked, 1);
    }

    #[test]
    fn absorb_queue_keeps_high_water_monotone() {
        let m = Metrics::default();
        m.absorb_queue(3, 7, 2);
        m.absorb_queue(0, 5, 4);
        let s = m.summary(1.0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.queue_high_water, 7);
        assert_eq!(s.producer_blocks, 4);
    }

    #[test]
    fn bucket_mapping_is_monotone() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        let mut prev = 0;
        for ns in [0u64, 1, 10, 1_000, 1_000_000, u64::MAX] {
            let b = bucket_of(ns);
            assert!(b >= prev);
            assert!(b < LATENCY_BUCKETS);
            prev = b;
        }
    }
}
