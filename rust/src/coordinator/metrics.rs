//! Coordinator metrics: throughput, latency distribution, queue stats.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared (lock-free) counters updated by workers.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs completed.
    pub jobs_done: AtomicU64,
    /// Jobs that failed (numerically dead chunks etc.).
    pub jobs_failed: AtomicU64,
    /// Total Baum-Welch timesteps processed.
    pub timesteps: AtomicU64,
    /// Total states processed.
    pub states: AtomicU64,
    /// Sum of per-job latencies (ns).
    pub latency_sum_ns: AtomicU64,
    /// Max per-job latency (ns).
    pub latency_max_ns: AtomicU64,
    /// Reads skipped during training (empty or numerically dead) —
    /// surfaced so dropped coverage is visible instead of silent.
    pub reads_skipped: AtomicU64,
}

impl Metrics {
    /// Record one finished job.
    pub fn record(&self, latency_ns: u64, timesteps: u64, states: u64) {
        self.jobs_done.fetch_add(1, Ordering::Relaxed);
        self.timesteps.fetch_add(timesteps, Ordering::Relaxed);
        self.states.fetch_add(states, Ordering::Relaxed);
        self.latency_sum_ns.fetch_add(latency_ns, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(latency_ns, Ordering::Relaxed);
    }

    /// Record a failed job.
    pub fn record_failure(&self) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record reads skipped while training a job.
    pub fn record_skipped_reads(&self, n: u64) {
        self.reads_skipped.fetch_add(n, Ordering::Relaxed);
    }

    /// Snapshot as a display-friendly summary.
    pub fn summary(&self, wall_seconds: f64) -> MetricsSummary {
        let done = self.jobs_done.load(Ordering::Relaxed);
        let sum = self.latency_sum_ns.load(Ordering::Relaxed);
        MetricsSummary {
            jobs_done: done,
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            timesteps: self.timesteps.load(Ordering::Relaxed),
            states: self.states.load(Ordering::Relaxed),
            reads_skipped: self.reads_skipped.load(Ordering::Relaxed),
            mean_latency_ms: if done > 0 { sum as f64 / done as f64 / 1e6 } else { 0.0 },
            max_latency_ms: self.latency_max_ns.load(Ordering::Relaxed) as f64 / 1e6,
            jobs_per_second: if wall_seconds > 0.0 { done as f64 / wall_seconds } else { 0.0 },
        }
    }
}

/// Snapshot of the metrics.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSummary {
    /// Jobs completed.
    pub jobs_done: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Baum-Welch timesteps processed.
    pub timesteps: u64,
    /// States processed.
    pub states: u64,
    /// Reads skipped during training across all jobs.
    pub reads_skipped: u64,
    /// Mean job latency (ms).
    pub mean_latency_ms: f64,
    /// Max job latency (ms).
    pub max_latency_ms: f64,
    /// Throughput (jobs/s).
    pub jobs_per_second: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let m = Metrics::default();
        m.record(1_000_000, 100, 5000);
        m.record(3_000_000, 200, 9000);
        m.record_failure();
        m.record_skipped_reads(3);
        let s = m.summary(2.0);
        assert_eq!(s.jobs_done, 2);
        assert_eq!(s.jobs_failed, 1);
        assert_eq!(s.timesteps, 300);
        assert_eq!(s.reads_skipped, 3);
        assert!((s.mean_latency_ms - 2.0).abs() < 1e-9);
        assert!((s.max_latency_ms - 3.0).abs() < 1e-9);
        assert!((s.jobs_per_second - 1.0).abs() < 1e-9);
    }
}
