//! Coordinator/server metrics: throughput, latency distribution, a
//! per-stage histogram family, work-mix counters, and queue
//! backpressure gauges.
//!
//! One [`Metrics`] instance is shared (lock-free) by every worker of a
//! coordinator run or a [`crate::server::Server`] lifetime.  Request
//! latencies and per-stage times feed fixed-bucket power-of-two
//! histograms ([`crate::obs::PowHist`]), so [`MetricsSummary`] reports
//! p50/p99 per stage — the live equivalent of the paper's §3
//! forward/backward/update bottleneck breakdown.  Work-mix counters
//! (gather dispatch rows, filter admit rate, stripe fill) expose the
//! kernel-selection decisions that are otherwise invisible from
//! whole-request latency.  Queue gauges mirror the most recently
//! absorbed [`crate::server::JobQueue`] snapshot, so the summary shows
//! whether `queue_depth` actually exerted backpressure.
//!
//! All recording sits at stage *boundaries* (the server's respond
//! path, never inside kernels or reductions): results are bit-identical
//! whether or not anything reads these counters, and each stage costs
//! at most one histogram record (two relaxed atomics) per request.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::baumwelch::{ReadStats, MAX_STRIPE};
use crate::obs::{HistSnapshot, PowHist};
use crate::server::queue::MAX_TRACKED_TENANTS;

/// Why a request failed, for the by-cause failure counters.  Wire
/// names (`name()`) appear in the `stats` / `tenants` commands and in
/// [`crate::server::ResponseBody::Failure`] lines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureCause {
    /// The request's deadline passed before it completed.
    DeadlineExceeded,
    /// The submitter cancelled the request.
    Cancelled,
    /// The job panicked and was contained at the per-job boundary.
    Panicked,
    /// Load shedding refused the request at admission.
    Shed,
}

impl FailureCause {
    /// Stable snake_case wire name.
    pub fn name(&self) -> &'static str {
        match self {
            FailureCause::DeadlineExceeded => "deadline_exceeded",
            FailureCause::Cancelled => "cancelled",
            FailureCause::Panicked => "panicked",
            FailureCause::Shed => "shed",
        }
    }
}

/// Pipeline stages with their own latency histogram, in exposition
/// order.  Label values of `aphmm_stage_seconds{stage="..."}`.
pub const STAGES: [&str; 5] = ["queue_wait", "cache_freeze", "forward", "backward", "update"];

/// Per-request stage durations handed to [`Metrics::record_stages`] by
/// the server's respond path.  Built from [`ReadStats`] plus the
/// queue-wait measured at pop time; a stage that did not run is 0 and
/// is not recorded (so e.g. `update` quantiles reflect only training
/// requests).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    /// Enqueue → worker pop.
    pub queue_wait_ns: u64,
    /// Prepared-cache freeze on miss (0 on hit).
    pub cache_freeze_ns: u64,
    /// Forward pass.
    pub forward_ns: u64,
    /// Backward pass fused with expectation accumulation.
    pub backward_ns: u64,
    /// Parameter update (M-step).
    pub update_ns: u64,
}

/// Shared (lock-free) counters updated by workers.
#[derive(Debug)]
pub struct Metrics {
    /// Jobs completed.
    pub jobs_done: AtomicU64,
    /// Jobs that failed (numerically dead chunks etc.).
    pub jobs_failed: AtomicU64,
    /// Total Baum-Welch timesteps processed.
    pub timesteps: AtomicU64,
    /// Total states processed.
    pub states: AtomicU64,
    /// Max per-job latency (ns).
    pub latency_max_ns: AtomicU64,
    /// Reads skipped during training (empty or numerically dead) —
    /// surfaced so dropped coverage is visible instead of silent.
    pub reads_skipped: AtomicU64,
    /// Current job-queue depth (gauge; latest absorbed snapshot).
    pub queue_depth: AtomicU64,
    /// Highest job-queue depth observed (monotone across absorbs).
    pub queue_high_water: AtomicU64,
    /// Producer admissions refused/blocked by the full queue (latest
    /// absorbed snapshot — monotone within one queue's lifetime).
    pub producer_blocks: AtomicU64,
    /// Failures whose deadline expired (subset of `jobs_failed`).
    pub failures_deadline_exceeded: AtomicU64,
    /// Failures cancelled by the submitter (subset of `jobs_failed`).
    pub failures_cancelled: AtomicU64,
    /// Jobs that panicked and were contained at the per-job boundary
    /// (subset of `jobs_failed`; surfaced as `pool_panics`).
    pub failures_panicked: AtomicU64,
    /// Requests refused by load shedding at admission (never admitted,
    /// so *not* counted in `jobs_failed`).
    pub failures_shed: AtomicU64,
    /// Requests refused at admission because their estimated
    /// full-matrix forward scratch exceeded `serve.max_scratch_bytes`
    /// with checkpointing disabled (never admitted, so *not* counted
    /// in `jobs_failed`).
    pub over_memory_refusals: AtomicU64,
    /// Highest per-read forward-row scratch observed across every
    /// request (bytes; high-water gauge, fed by
    /// [`Metrics::absorb_read_stats`] via `fetch_max`).  Under
    /// checkpointed scratch this stays O(√T·states) even for reads
    /// whose full matrix would not fit the budget.
    pub peak_scratch_bytes: AtomicU64,
    /// Training epochs completed across all jobs (one per full-batch
    /// iteration or per minibatch/Viterbi epoch).
    pub epochs: AtomicU64,
    /// Minibatches processed across all jobs (0 under full batch).
    pub minibatches: AtomicU64,
    /// Sequences pulled through streaming read sources across all jobs
    /// (0 for purely in-memory full-batch training).
    pub sequences_streamed: AtomicU64,
    /// Sparse-gather rows dispatched down the CSR row path.
    pub rows_csr: AtomicU64,
    /// Sparse-gather rows dispatched down the dense-tile row path.
    pub rows_dense_tile: AtomicU64,
    /// Filter invocations (one per filtered timestep).
    pub filter_calls: AtomicU64,
    /// States offered to the filter.
    pub filter_states_in: AtomicU64,
    /// States admitted by the filter (`out/in` = admit rate).
    pub filter_states_out: AtomicU64,
    /// Striped multi-read kernel passes.
    pub stripe_passes: AtomicU64,
    /// Reads carried by those passes (`reads/passes` = mean fill out
    /// of [`MAX_STRIPE`]).
    pub stripe_reads: AtomicU64,
    /// Whole-request latency histogram (success and failure).
    request_hist: PowHist,
    /// Per-stage latency histograms, [`STAGES`] order.
    stage_hists: [PowHist; STAGES.len()],
    /// Stripe-fill distribution: slot `f-1` counts striped score
    /// passes that carried exactly `f` reads.
    stripe_fill: [AtomicU64; MAX_STRIPE],
    /// When this instance was created — the one wall-clock anchor all
    /// throughput rates derive from, so `stats`, `tenants`, and
    /// `metrics` agree.
    started: Instant,
    /// Per-tenant gauges (multi-tenant serving; empty for coordinator
    /// runs).  BTreeMap keeps snapshot order deterministic.
    tenants: Mutex<BTreeMap<String, TenantGauges>>,
}

/// Per-tenant counter block inside [`Metrics`].  Completion counts are
/// recorded by workers; the admission-side gauges mirror the latest
/// absorbed [`crate::server::TenantStats`] snapshot.
#[derive(Clone, Copy, Debug, Default)]
struct TenantGauges {
    admitted: u64,
    completed: u64,
    failed: u64,
    quota_refusals: u64,
    queued: u64,
    in_flight: u64,
    deadline_exceeded: u64,
    cancelled: u64,
    panicked: u64,
    /// Mirrors the queue's admission-side shed counter (absorbed, not
    /// worker-recorded — shed requests never reach a worker).
    shed: u64,
    /// Highest per-read forward-row scratch this tenant's requests
    /// reached (bytes; high-water, worker-recorded at respond time).
    peak_scratch_bytes: u64,
}

// Tenant-map bounding (tenant ids are client-controlled and must not
// grow the map, or every summary, without limit): the accurate
// eviction runs in [`Metrics::evict_stale_tenants`], fed the queue's
// *current* tenant set by the server right after it absorbed fresh
// gauges — the mirrored gauges alone can be stale and must not decide
// evictions, or a tenant with real queued work could lose its
// counters.  `record_tenant_done` only refuses to create brand-new
// entries past a generous overflow bound (attribution for overflow
// tenants is dropped, live entries are never evicted there).

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            jobs_done: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            timesteps: AtomicU64::new(0),
            states: AtomicU64::new(0),
            latency_max_ns: AtomicU64::new(0),
            reads_skipped: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            queue_high_water: AtomicU64::new(0),
            producer_blocks: AtomicU64::new(0),
            failures_deadline_exceeded: AtomicU64::new(0),
            failures_cancelled: AtomicU64::new(0),
            failures_panicked: AtomicU64::new(0),
            failures_shed: AtomicU64::new(0),
            over_memory_refusals: AtomicU64::new(0),
            peak_scratch_bytes: AtomicU64::new(0),
            epochs: AtomicU64::new(0),
            minibatches: AtomicU64::new(0),
            sequences_streamed: AtomicU64::new(0),
            rows_csr: AtomicU64::new(0),
            rows_dense_tile: AtomicU64::new(0),
            filter_calls: AtomicU64::new(0),
            filter_states_in: AtomicU64::new(0),
            filter_states_out: AtomicU64::new(0),
            stripe_passes: AtomicU64::new(0),
            stripe_reads: AtomicU64::new(0),
            request_hist: PowHist::default(),
            stage_hists: std::array::from_fn(|_| PowHist::default()),
            stripe_fill: std::array::from_fn(|_| AtomicU64::new(0)),
            started: Instant::now(),
            tenants: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    /// Record one finished job.
    pub fn record(&self, latency_ns: u64, timesteps: u64, states: u64) {
        self.jobs_done.fetch_add(1, Ordering::Relaxed);
        self.timesteps.fetch_add(timesteps, Ordering::Relaxed);
        self.states.fetch_add(states, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(latency_ns, Ordering::Relaxed);
        self.request_hist.record(latency_ns);
    }

    /// Record a failed job.
    pub fn record_failure(&self) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a failed request *with* its latency and cause: failures
    /// feed the latency histogram too (a fleet whose p99 is dominated
    /// by requests that die at their deadline must show it), and the
    /// cause increments its by-cause counter.  `cause = None` is a
    /// plain execution error.
    pub fn record_failed_request(&self, latency_ns: u64, cause: Option<FailureCause>) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(latency_ns, Ordering::Relaxed);
        self.request_hist.record(latency_ns);
        match cause {
            Some(FailureCause::DeadlineExceeded) => {
                self.failures_deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            }
            Some(FailureCause::Cancelled) => {
                self.failures_cancelled.fetch_add(1, Ordering::Relaxed);
            }
            Some(FailureCause::Panicked) => {
                self.failures_panicked.fetch_add(1, Ordering::Relaxed);
            }
            Some(FailureCause::Shed) => {
                self.failures_shed.fetch_add(1, Ordering::Relaxed);
            }
            None => {}
        }
    }

    /// Record a request refused by load shedding (admission-side: the
    /// request was never a job, so `jobs_failed` is untouched).
    pub fn record_shed(&self) {
        self.failures_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request refused at admission because its estimated
    /// full-matrix scratch exceeded the server's memory budget with
    /// checkpointing disabled (admission-side, like [`record_shed`]).
    ///
    /// [`record_shed`]: Metrics::record_shed
    pub fn record_over_memory_refusal(&self) {
        self.over_memory_refusals.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the peak forward-row scratch one request for `tenant`
    /// reached (bytes).  Both the process-wide and the per-tenant
    /// gauges are high-water marks, so repeated records never lose a
    /// peak.  Same overflow bound as [`record_tenant_done`] for the
    /// per-tenant entry; the process-wide gauge always updates.
    ///
    /// [`record_tenant_done`]: Metrics::record_tenant_done
    pub fn record_tenant_scratch(&self, tenant: &str, bytes: u64) {
        self.peak_scratch_bytes.fetch_max(bytes, Ordering::Relaxed);
        if bytes == 0 {
            return;
        }
        let mut tenants = self.tenants.lock().unwrap();
        if !tenants.contains_key(tenant) && tenants.len() >= MAX_TRACKED_TENANTS * 4 {
            return;
        }
        let t = tenants.entry(tenant.to_string()).or_default();
        t.peak_scratch_bytes = t.peak_scratch_bytes.max(bytes);
    }

    /// Record reads skipped while training a job.
    pub fn record_skipped_reads(&self, n: u64) {
        self.reads_skipped.fetch_add(n, Ordering::Relaxed);
    }

    /// Fold one training run's schedule counters in (epochs run,
    /// minibatches processed, sequences streamed from its source).
    pub fn record_train_progress(&self, epochs: u64, minibatches: u64, sequences_streamed: u64) {
        if epochs > 0 {
            self.epochs.fetch_add(epochs, Ordering::Relaxed);
        }
        if minibatches > 0 {
            self.minibatches.fetch_add(minibatches, Ordering::Relaxed);
        }
        if sequences_streamed > 0 {
            self.sequences_streamed.fetch_add(sequences_streamed, Ordering::Relaxed);
        }
    }

    /// Feed one request's stage durations into the per-stage histogram
    /// family.  A zero duration means the stage did not run and is not
    /// recorded, so each stage's quantiles describe only requests that
    /// exercised it (`update` → training, `cache_freeze` → cache
    /// misses).
    pub fn record_stages(&self, t: &StageTimes) {
        let times = [
            t.queue_wait_ns,
            t.cache_freeze_ns,
            t.forward_ns,
            t.backward_ns,
            t.update_ns,
        ];
        for (hist, &ns) in self.stage_hists.iter().zip(times.iter()) {
            if ns > 0 {
                hist.record(ns);
            }
        }
    }

    /// Fold one request's work-mix counters in: gather dispatch rows,
    /// filter admit rate, and stripe totals from its [`ReadStats`].
    pub fn absorb_read_stats(&self, stats: &ReadStats) {
        let f = &stats.filter_stats;
        if f.rows_csr > 0 {
            self.rows_csr.fetch_add(f.rows_csr, Ordering::Relaxed);
        }
        if f.rows_dense_tile > 0 {
            self.rows_dense_tile.fetch_add(f.rows_dense_tile, Ordering::Relaxed);
        }
        if f.calls > 0 {
            self.filter_calls.fetch_add(f.calls, Ordering::Relaxed);
            self.filter_states_in.fetch_add(f.states_in, Ordering::Relaxed);
            self.filter_states_out.fetch_add(f.states_out, Ordering::Relaxed);
        }
        if stats.stripe_passes > 0 {
            self.stripe_passes.fetch_add(stats.stripe_passes, Ordering::Relaxed);
            self.stripe_reads.fetch_add(stats.stripe_reads, Ordering::Relaxed);
        }
        if stats.peak_scratch_bytes > 0 {
            self.peak_scratch_bytes.fetch_max(stats.peak_scratch_bytes, Ordering::Relaxed);
        }
        self.record_train_progress(stats.epochs, stats.minibatches, stats.sequences_streamed);
    }

    /// Record one striped score pass that carried `fill` reads (out of
    /// [`MAX_STRIPE`]).  Called by the server's micro-batch dispatch,
    /// which knows the exact chunking the striped kernel will use.
    pub fn record_stripe_fill(&self, fill: usize) {
        let f = fill.clamp(1, MAX_STRIPE);
        self.stripe_fill[f - 1].fetch_add(1, Ordering::Relaxed);
    }

    /// Fold a job-queue gauge snapshot in: `depth` and `blocks` mirror
    /// the snapshot (idempotent for one queue), `high_water` is kept
    /// monotone so repeated absorbs never lose the peak.
    pub fn absorb_queue(&self, depth: u64, high_water: u64, blocks: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_high_water.fetch_max(high_water, Ordering::Relaxed);
        self.producer_blocks.store(blocks, Ordering::Relaxed);
    }

    /// Record a completed (or failed) request for `tenant`.  Past the
    /// overflow bound, completions of brand-new tenants go unattributed
    /// (the aggregate counters still see them) rather than evicting a
    /// live entry on possibly-stale gauges.
    pub fn record_tenant_done(&self, tenant: &str, ok: bool) {
        let mut tenants = self.tenants.lock().unwrap();
        if !tenants.contains_key(tenant) && tenants.len() >= MAX_TRACKED_TENANTS * 4 {
            return;
        }
        let t = tenants.entry(tenant.to_string()).or_default();
        if ok {
            t.completed += 1;
        } else {
            t.failed += 1;
        }
    }

    /// Record a failed request for `tenant` with its cause (same
    /// overflow bound as [`record_tenant_done`]).  Increments both the
    /// tenant's `failed` total and the by-cause counter.
    ///
    /// [`record_tenant_done`]: Metrics::record_tenant_done
    pub fn record_tenant_failure(&self, tenant: &str, cause: Option<FailureCause>) {
        let mut tenants = self.tenants.lock().unwrap();
        if !tenants.contains_key(tenant) && tenants.len() >= MAX_TRACKED_TENANTS * 4 {
            return;
        }
        let t = tenants.entry(tenant.to_string()).or_default();
        t.failed += 1;
        match cause {
            Some(FailureCause::DeadlineExceeded) => t.deadline_exceeded += 1,
            Some(FailureCause::Cancelled) => t.cancelled += 1,
            Some(FailureCause::Panicked) => t.panicked += 1,
            Some(FailureCause::Shed) => t.shed += 1,
            None => {}
        }
    }

    /// Fold one tenant's admission-side gauge snapshot in (idempotent
    /// for one queue — the counters mirror the snapshot).
    pub fn absorb_tenant(
        &self,
        tenant: &str,
        admitted: u64,
        quota_refusals: u64,
        queued: u64,
        in_flight: u64,
        shed: u64,
    ) {
        let mut tenants = self.tenants.lock().unwrap();
        let t = tenants.entry(tenant.to_string()).or_default();
        t.admitted = admitted;
        t.quota_refusals = quota_refusals;
        t.queued = queued;
        t.in_flight = in_flight;
        t.shed = shed;
    }

    /// Reconcile the tenant map against `active` — the queue's
    /// *current* tenant set, whose just-absorbed gauges are
    /// authoritative — then bound it past [`MAX_TRACKED_TENANTS`].  A
    /// tenant absent from `active` has nothing queued or in flight
    /// (the queue evicts only idle entries, so absence means idle):
    /// its mirrored gauges are cleared first, so a stale snapshot
    /// taken while it was busy can neither pin the entry here forever
    /// nor report phantom queued work.  A tenant with real work is in
    /// `active` and can never be evicted.
    pub fn evict_stale_tenants(&self, active: &[&str]) {
        let active: std::collections::HashSet<&str> = active.iter().copied().collect();
        let mut tenants = self.tenants.lock().unwrap();
        for (name, t) in tenants.iter_mut() {
            if !active.contains(name.as_str()) {
                t.queued = 0;
                t.in_flight = 0;
            }
        }
        if tenants.len() > MAX_TRACKED_TENANTS {
            tenants.retain(|name, t| {
                t.queued > 0 || t.in_flight > 0 || active.contains(name.as_str())
            });
        }
    }

    /// Seconds since this instance was created — the wall-time anchor
    /// behind every throughput rate in the exposition.
    pub fn wall_seconds(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Snapshot of the whole-request latency histogram (for the
    /// Prometheus exposition).
    pub fn request_hist_snapshot(&self) -> HistSnapshot {
        self.request_hist.snapshot()
    }

    /// Snapshots of the per-stage histograms, paired with their
    /// [`STAGES`] label values.
    pub fn stage_snapshots(&self) -> Vec<(&'static str, HistSnapshot)> {
        STAGES
            .iter()
            .zip(self.stage_hists.iter())
            .map(|(name, h)| (*name, h.snapshot()))
            .collect()
    }

    /// Stripe-fill counts: slot `f-1` holds the number of striped
    /// score passes that carried exactly `f` reads.
    pub fn stripe_fill_counts(&self) -> Vec<u64> {
        self.stripe_fill.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Snapshot as a display-friendly summary.  Wall time (and thus
    /// every rate) is derived from the instance's own start `Instant`,
    /// so `stats`, `tenants`, and `metrics` report consistent
    /// throughput.
    pub fn summary(&self) -> MetricsSummary {
        let done = self.jobs_done.load(Ordering::Relaxed);
        let req = self.request_hist.snapshot();
        let wall_seconds = self.wall_seconds();
        let mut tenants: Vec<TenantSummary> = self
            .tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(name, t)| TenantSummary {
                tenant: name.clone(),
                admitted: t.admitted,
                completed: t.completed,
                failed: t.failed,
                quota_refusals: t.quota_refusals,
                queued: t.queued,
                in_flight: t.in_flight,
                deadline_exceeded: t.deadline_exceeded,
                cancelled: t.cancelled,
                panicked: t.panicked,
                shed: t.shed,
                peak_scratch_bytes: t.peak_scratch_bytes,
            })
            .collect();
        // The BTreeMap already iterates in id order; the explicit sort
        // pins the wire-visible ordering contract (scrapers diff the
        // `tenants` line) independently of the map's implementation.
        tenants.sort_by(|a, b| a.tenant.cmp(&b.tenant));
        let stages = self
            .stage_snapshots()
            .into_iter()
            .map(|(stage, s)| StageSummary {
                stage,
                count: s.count(),
                total_seconds: s.sum as f64 / 1e9,
                p50_ms: s.quantile(0.50) as f64 / 1e6,
                p99_ms: s.quantile(0.99) as f64 / 1e6,
            })
            .collect();
        MetricsSummary {
            tenants,
            stages,
            wall_seconds,
            jobs_done: done,
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            timesteps: self.timesteps.load(Ordering::Relaxed),
            states: self.states.load(Ordering::Relaxed),
            reads_skipped: self.reads_skipped.load(Ordering::Relaxed),
            mean_latency_ms: if done > 0 { req.sum as f64 / done as f64 / 1e6 } else { 0.0 },
            max_latency_ms: self.latency_max_ns.load(Ordering::Relaxed) as f64 / 1e6,
            latency_p50_ms: req.quantile(0.50) as f64 / 1e6,
            latency_p99_ms: req.quantile(0.99) as f64 / 1e6,
            jobs_per_second: if wall_seconds > 0.0 { done as f64 / wall_seconds } else { 0.0 },
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            queue_high_water: self.queue_high_water.load(Ordering::Relaxed),
            producer_blocks: self.producer_blocks.load(Ordering::Relaxed),
            deadline_exceeded: self.failures_deadline_exceeded.load(Ordering::Relaxed),
            cancelled: self.failures_cancelled.load(Ordering::Relaxed),
            pool_panics: self.failures_panicked.load(Ordering::Relaxed),
            shed: self.failures_shed.load(Ordering::Relaxed),
            over_memory_refusals: self.over_memory_refusals.load(Ordering::Relaxed),
            peak_scratch_bytes: self.peak_scratch_bytes.load(Ordering::Relaxed),
            epochs: self.epochs.load(Ordering::Relaxed),
            minibatches: self.minibatches.load(Ordering::Relaxed),
            sequences_streamed: self.sequences_streamed.load(Ordering::Relaxed),
            rows_csr: self.rows_csr.load(Ordering::Relaxed),
            rows_dense_tile: self.rows_dense_tile.load(Ordering::Relaxed),
            filter_calls: self.filter_calls.load(Ordering::Relaxed),
            filter_states_in: self.filter_states_in.load(Ordering::Relaxed),
            filter_states_out: self.filter_states_out.load(Ordering::Relaxed),
            stripe_passes: self.stripe_passes.load(Ordering::Relaxed),
            stripe_reads: self.stripe_reads.load(Ordering::Relaxed),
        }
    }
}

/// One tenant's slice of a [`MetricsSummary`].
#[derive(Clone, Debug, Default)]
pub struct TenantSummary {
    /// Tenant id.
    pub tenant: String,
    /// Requests admitted to the queue.
    pub admitted: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that answered an error.
    pub failed: u64,
    /// Admissions refused/blocked by a tenant quota cap.
    pub quota_refusals: u64,
    /// Requests currently queued (gauge).
    pub queued: u64,
    /// Requests currently in flight (gauge).
    pub in_flight: u64,
    /// Failures whose deadline expired.
    pub deadline_exceeded: u64,
    /// Failures cancelled by the submitter.
    pub cancelled: u64,
    /// Failures contained from a panicking job.
    pub panicked: u64,
    /// Admissions refused by load shedding.
    pub shed: u64,
    /// Highest per-read forward-row scratch this tenant reached
    /// (bytes; high-water mark).
    pub peak_scratch_bytes: u64,
}

/// One stage's slice of a [`MetricsSummary`] — the live §3-style
/// breakdown (count, total time, bucket-resolution quantiles).
#[derive(Clone, Debug, Default)]
pub struct StageSummary {
    /// Stage label (one of [`STAGES`]).
    pub stage: &'static str,
    /// Requests that exercised this stage.
    pub count: u64,
    /// Total time spent in this stage (s).
    pub total_seconds: f64,
    /// Median stage time (ms, histogram bucket upper bound).
    pub p50_ms: f64,
    /// 99th-percentile stage time (ms, histogram bucket upper bound).
    pub p99_ms: f64,
}

/// Snapshot of the metrics.
#[derive(Clone, Debug)]
pub struct MetricsSummary {
    /// Jobs completed.
    pub jobs_done: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Baum-Welch timesteps processed.
    pub timesteps: u64,
    /// States processed.
    pub states: u64,
    /// Reads skipped during training across all jobs.
    pub reads_skipped: u64,
    /// Mean job latency (ms).
    pub mean_latency_ms: f64,
    /// Max job latency (ms).
    pub max_latency_ms: f64,
    /// Median job latency (ms, histogram bucket upper bound).
    pub latency_p50_ms: f64,
    /// 99th-percentile job latency (ms, histogram bucket upper bound).
    pub latency_p99_ms: f64,
    /// Throughput (jobs/s) over [`MetricsSummary::wall_seconds`].
    pub jobs_per_second: f64,
    /// Seconds since the metrics instance (≈ the server) started —
    /// the denominator of every rate in this snapshot.
    pub wall_seconds: f64,
    /// Job-queue depth at the last absorbed snapshot.
    pub queue_depth: u64,
    /// Highest job-queue depth observed.
    pub queue_high_water: u64,
    /// Producer admissions refused/blocked by a full queue.
    pub producer_blocks: u64,
    /// Failures whose deadline expired (subset of `jobs_failed`).
    pub deadline_exceeded: u64,
    /// Failures cancelled by the submitter (subset of `jobs_failed`).
    pub cancelled: u64,
    /// Jobs that panicked and were contained at the per-job boundary.
    pub pool_panics: u64,
    /// Requests refused by load shedding at admission.
    pub shed: u64,
    /// Requests refused at admission for exceeding the memory budget
    /// with checkpointing disabled.
    pub over_memory_refusals: u64,
    /// Highest per-read forward-row scratch observed (bytes).
    pub peak_scratch_bytes: u64,
    /// Training epochs completed across all jobs.
    pub epochs: u64,
    /// Minibatches processed across all jobs (0 under full batch).
    pub minibatches: u64,
    /// Sequences pulled through streaming read sources.
    pub sequences_streamed: u64,
    /// Sparse-gather rows dispatched down the CSR row path.
    pub rows_csr: u64,
    /// Sparse-gather rows dispatched down the dense-tile row path.
    pub rows_dense_tile: u64,
    /// Filter invocations.
    pub filter_calls: u64,
    /// States offered to the filter.
    pub filter_states_in: u64,
    /// States admitted by the filter.
    pub filter_states_out: u64,
    /// Striped multi-read kernel passes.
    pub stripe_passes: u64,
    /// Reads carried by striped passes.
    pub stripe_reads: u64,
    /// Per-stage breakdown, [`STAGES`] order.
    pub stages: Vec<StageSummary>,
    /// Per-tenant gauges, sorted by tenant id (empty for coordinator
    /// runs — only the serving layer is multi-tenant).
    pub tenants: Vec<TenantSummary>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let metrics = Metrics::default();
        metrics.record(1_000_000, 50, 500);
        metrics.record(3_000_000, 70, 700);
        metrics.record_failure();

        let s = metrics.summary();
        assert_eq!(s.jobs_done, 2);
        assert_eq!(s.jobs_failed, 1);
        assert_eq!(s.timesteps, 120);
        assert_eq!(s.states, 1200);
        assert!((s.mean_latency_ms - 2.0).abs() < 1e-9);
        assert!((s.max_latency_ms - 3.0).abs() < 1e-9);
        // Wall time is derived internally from the start Instant, so
        // the rate is consistent with the reported wall_seconds.
        assert!(s.wall_seconds > 0.0);
        assert!((s.jobs_per_second - 2.0 / s.wall_seconds).abs() < 1.0);
    }

    #[test]
    fn histogram_quantiles_bracket_the_latencies() {
        let metrics = Metrics::default();
        // 99 fast jobs at ~1 µs, 1 slow at ~1 ms.
        for _ in 0..99 {
            metrics.record(1_000, 1, 1);
        }
        metrics.record(1_000_000, 1, 1);
        let s = metrics.summary();
        // p50 in the microsecond bucket (bounds are powers of two).
        assert!(s.latency_p50_ms > 0.0005 && s.latency_p50_ms < 0.005, "{}", s.latency_p50_ms);
        // p99 still fast (99 of 100), max is the slow one.
        assert!(s.latency_p99_ms < 0.005, "{}", s.latency_p99_ms);
        assert!((s.max_latency_ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_jobs_have_zero_quantiles() {
        let s = Metrics::default().summary();
        assert_eq!(s.latency_p50_ms, 0.0);
        assert_eq!(s.latency_p99_ms, 0.0);
        assert_eq!(s.mean_latency_ms, 0.0);
        assert!(s.stages.iter().all(|st| st.count == 0));
    }

    #[test]
    fn stage_histograms_record_only_stages_that_ran() {
        let metrics = Metrics::default();
        metrics.record_stages(&StageTimes {
            queue_wait_ns: 10_000,
            cache_freeze_ns: 0,
            forward_ns: 1_000_000,
            backward_ns: 2_000_000,
            update_ns: 0,
        });
        metrics.record_stages(&StageTimes {
            queue_wait_ns: 20_000,
            cache_freeze_ns: 500_000,
            forward_ns: 1_000_000,
            backward_ns: 0,
            update_ns: 4_000_000,
        });
        let s = metrics.summary();
        let by_name = |n: &str| s.stages.iter().find(|st| st.stage == n).unwrap().clone();
        assert_eq!(by_name("queue_wait").count, 2);
        assert_eq!(by_name("cache_freeze").count, 1);
        assert_eq!(by_name("forward").count, 2);
        assert_eq!(by_name("backward").count, 1);
        assert_eq!(by_name("update").count, 1);
        let fwd = by_name("forward");
        assert!((fwd.total_seconds - 0.002).abs() < 1e-9);
        assert!(fwd.p50_ms > 0.5 && fwd.p99_ms < 5.0);
        // Summary order matches the exposition order.
        let names: Vec<&str> = s.stages.iter().map(|st| st.stage).collect();
        assert_eq!(names, STAGES.to_vec());
    }

    #[test]
    fn read_stats_feed_work_mix_counters() {
        use crate::baumwelch::FilterStats;
        let metrics = Metrics::default();
        metrics.absorb_read_stats(&ReadStats {
            filter_stats: FilterStats {
                time_ns: 5,
                calls: 10,
                states_in: 100,
                states_out: 40,
                rows_csr: 7,
                rows_dense_tile: 3,
            },
            stripe_passes: 2,
            stripe_reads: 12,
            ..Default::default()
        });
        metrics.record_stripe_fill(MAX_STRIPE);
        metrics.record_stripe_fill(4);
        metrics.record_stripe_fill(0); // clamped to 1
        let s = metrics.summary();
        assert_eq!(s.rows_csr, 7);
        assert_eq!(s.rows_dense_tile, 3);
        assert_eq!(s.filter_calls, 10);
        assert_eq!(s.filter_states_in, 100);
        assert_eq!(s.filter_states_out, 40);
        assert_eq!(s.stripe_passes, 2);
        assert_eq!(s.stripe_reads, 12);
        let fill = metrics.stripe_fill_counts();
        assert_eq!(fill.len(), MAX_STRIPE);
        assert_eq!(fill[MAX_STRIPE - 1], 1);
        assert_eq!(fill[3], 1);
        assert_eq!(fill[0], 1);
    }

    #[test]
    fn tenant_gauges_fold_into_the_summary_sorted() {
        let metrics = Metrics::default();
        metrics.record_tenant_done("zeta", true);
        metrics.record_tenant_done("alpha", true);
        metrics.record_tenant_done("alpha", false);
        metrics.absorb_tenant("alpha", 5, 2, 1, 1, 0);
        let s = metrics.summary();
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[0].tenant, "alpha");
        assert_eq!(s.tenants[1].tenant, "zeta");
        assert!(s.tenants.windows(2).all(|w| w[0].tenant < w[1].tenant));
        assert_eq!(s.tenants[0].completed, 1);
        assert_eq!(s.tenants[0].failed, 1);
        assert_eq!(s.tenants[0].admitted, 5);
        assert_eq!(s.tenants[0].quota_refusals, 2);
        assert_eq!(s.tenants[1].completed, 1);
    }

    #[test]
    fn failures_count_by_cause_and_feed_the_histogram() {
        let metrics = Metrics::default();
        metrics.record_failed_request(1_000_000, Some(FailureCause::DeadlineExceeded));
        metrics.record_failed_request(2_000_000, Some(FailureCause::Cancelled));
        metrics.record_failed_request(3_000_000, Some(FailureCause::Panicked));
        metrics.record_failed_request(500_000, None);
        metrics.record_shed();
        let s = metrics.summary();
        assert_eq!(s.jobs_failed, 4);
        assert_eq!(s.deadline_exceeded, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.pool_panics, 1);
        assert_eq!(s.shed, 1);
        // Failed-request latencies must appear in the histogram.
        assert!(s.latency_p99_ms > 0.0);
        assert!((s.max_latency_ms - 3.0).abs() < 1e-9);
    }

    #[test]
    fn tenant_failures_count_by_cause() {
        let metrics = Metrics::default();
        metrics.record_tenant_failure("t", Some(FailureCause::DeadlineExceeded));
        metrics.record_tenant_failure("t", Some(FailureCause::Panicked));
        metrics.record_tenant_failure("t", None);
        let s = metrics.summary();
        assert_eq!(s.tenants[0].failed, 3);
        assert_eq!(s.tenants[0].deadline_exceeded, 1);
        assert_eq!(s.tenants[0].panicked, 1);
        assert_eq!(s.tenants[0].cancelled, 0);
    }

    #[test]
    fn scratch_gauges_are_high_water_marks() {
        let metrics = Metrics::default();
        metrics.record_tenant_scratch("t", 4096);
        metrics.record_tenant_scratch("t", 1024); // lower — must not regress
        metrics.record_tenant_scratch("u", 2048);
        metrics.record_over_memory_refusal();
        // The coordinator path feeds the process gauge via read stats.
        metrics.absorb_read_stats(&ReadStats {
            peak_scratch_bytes: 8192,
            ..Default::default()
        });
        let s = metrics.summary();
        assert_eq!(s.peak_scratch_bytes, 8192);
        assert_eq!(s.over_memory_refusals, 1);
        let by_name = |n: &str| s.tenants.iter().find(|t| t.tenant == n).unwrap().clone();
        assert_eq!(by_name("t").peak_scratch_bytes, 4096);
        assert_eq!(by_name("u").peak_scratch_bytes, 2048);
    }

    #[test]
    fn absorb_queue_keeps_high_water_monotone() {
        let metrics = Metrics::default();
        metrics.absorb_queue(5, 10, 2);
        metrics.absorb_queue(1, 3, 4);
        let s = metrics.summary();
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.queue_high_water, 10);
        assert_eq!(s.producer_blocks, 4);
    }
}
