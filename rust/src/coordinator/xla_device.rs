//! Shared XLA "device" thread.
//!
//! PJRT client handles are not `Send`-safe across arbitrary threads, and
//! an accelerator is a shared resource anyway — so one device thread
//! owns the [`ArtifactStore`] and serves banded expectation requests
//! over a channel, exactly the host↔accelerator split of the paper's
//! Supplemental S3 execution flow.  Workers hold a cloneable
//! [`XlaHandle`].

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::baumwelch::BandedBwSums;
use crate::error::{ApHmmError, Result};
use crate::phmm::{BandedPhmm, Phmm};
use crate::runtime::{ArtifactStore, XlaBandedEngine};
use crate::seq::Sequence;

enum Request {
    BwSums { banded: BandedPhmm, seq: Sequence, reply: mpsc::Sender<Result<BandedBwSums>> },
    Shutdown,
}

/// Cloneable handle for submitting work to the device thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: mpsc::Sender<Request>,
}

impl XlaHandle {
    /// One expectation pass on the device.
    pub fn bw_sums(&self, banded: &BandedPhmm, seq: &Sequence) -> Result<BandedBwSums> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::BwSums { banded: banded.clone(), seq: seq.clone(), reply: reply_tx })
            .map_err(|_| ApHmmError::Coordinator("XLA device thread is gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| ApHmmError::Coordinator("XLA device dropped the reply".into()))?
    }
}

/// The device thread plus its shutdown plumbing.
pub struct XlaDevice {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl XlaDevice {
    /// Spawn the device thread; fails fast if the artifacts are missing
    /// or do not compile.
    pub fn spawn(artifacts_dir: std::path::PathBuf) -> Result<XlaDevice> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::spawn(move || {
            let store = match ArtifactStore::load(&artifacts_dir) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Shutdown => break,
                    Request::BwSums { banded, seq, reply } => {
                        let result = XlaBandedEngine::for_shape(
                            &store,
                            banded.n,
                            banded.w,
                            banded.sigma,
                            seq.len(),
                        )
                        .and_then(|engine| engine.bw_sums(&banded, &seq));
                        let _ = reply.send(result);
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| ApHmmError::Coordinator("XLA device thread died during init".into()))??;
        Ok(XlaDevice { tx, join: Some(join) })
    }

    /// A handle for workers.
    pub fn handle(&self) -> XlaHandle {
        XlaHandle { tx: self.tx.clone() }
    }
}

impl Drop for XlaDevice {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Training statistics of the XLA path.
#[derive(Clone, Copy, Debug)]
pub struct XlaTrainStats {
    /// Mean per-read log-likelihood of the final iteration.
    pub mean_loglik: f64,
    /// Total timesteps processed.
    pub timesteps: u64,
    /// Total state-steps (N × timesteps; the dense engine touches all).
    pub states: u64,
    /// Reads skipped (empty or numerically dead), summed over
    /// iterations — surfaced in the coordinator metrics.
    pub reads_skipped: u64,
}

/// Batch-EM training through the device: accumulate banded sums across
/// reads, apply, repeat.  Writes the final parameters back into `graph`.
pub fn train_via_xla(
    handle: &XlaHandle,
    graph: &mut Phmm,
    reads: &[Sequence],
    iters: usize,
) -> Result<XlaTrainStats> {
    let mut banded = graph.to_banded()?;
    let mut stats = XlaTrainStats {
        mean_loglik: f64::NEG_INFINITY,
        timesteps: 0,
        states: 0,
        reads_skipped: 0,
    };
    for _ in 0..iters.max(1) {
        let mut total = BandedBwSums::zeros(banded.n, banded.w, banded.sigma);
        let mut n_reads = 0u64;
        for read in reads {
            if read.is_empty() {
                stats.reads_skipped += 1;
                continue;
            }
            match handle.bw_sums(&banded, read) {
                Ok(sums) => {
                    total.add(&sums);
                    n_reads += 1;
                    stats.timesteps += read.len() as u64;
                    stats.states += (read.len() * banded.n) as u64;
                }
                Err(e @ ApHmmError::Runtime(_)) => return Err(e),
                Err(_) => {
                    // Numerically dead read — counted, then skipped.
                    stats.reads_skipped += 1;
                    continue;
                }
            }
        }
        if n_reads == 0 {
            return Err(ApHmmError::Numerical("no read survived XLA training".into()));
        }
        stats.mean_loglik = total.loglik as f64 / n_reads as f64;
        total.apply(&mut banded);
    }
    graph.update_from_banded(&banded)?;
    Ok(stats)
}
