//! Shared XLA "device" thread and the [`XlaEngine`] backend built on it.
//!
//! PJRT client handles are not `Send`-safe across arbitrary threads, and
//! an accelerator is a shared resource anyway — so one device thread
//! owns the [`ArtifactStore`] and serves banded expectation requests
//! over a channel, exactly the host↔accelerator split of the paper's
//! Supplemental S3 execution flow.  Workers hold a cloneable
//! [`XlaHandle`]; [`XlaEngine`] wraps one behind the
//! [`ExpectationEngine`] trait, so the generic training loop drives the
//! device exactly the way it drives the in-process engines.  Real PJRT
//! execution is gated behind the `pjrt` cargo feature (the `xla`
//! feature compiles the same surface against stubs).

use std::sync::mpsc;
use std::sync::Mutex;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::baumwelch::{
    BandedAcc, BandedBwSums, ExpectationEngine, FilterStats, ForwardOptions, ReadStats,
    ScoreResult,
};
use crate::error::{ApHmmError, Result};
use crate::phmm::{BandedPhmm, Phmm};
use crate::runtime::{ArtifactStore, XlaBandedEngine};
use crate::seq::Sequence;

enum Request {
    BwSums { banded: BandedPhmm, seq: Sequence, reply: mpsc::Sender<Result<BandedBwSums>> },
    Score { banded: BandedPhmm, seq: Sequence, reply: mpsc::Sender<Result<f64>> },
    Shutdown,
}

/// Cloneable handle for submitting work to the device thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: mpsc::Sender<Request>,
}

impl XlaHandle {
    /// One expectation pass on the device.
    pub fn bw_sums(&self, banded: &BandedPhmm, seq: &Sequence) -> Result<BandedBwSums> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::BwSums { banded: banded.clone(), seq: seq.clone(), reply: reply_tx })
            .map_err(|_| ApHmmError::Coordinator("XLA device thread is gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| ApHmmError::Coordinator("XLA device dropped the reply".into()))?
    }

    /// Forward-only score on the device (the forward artifact; half the
    /// work and payload of a full expectation pass).
    pub fn score(&self, banded: &BandedPhmm, seq: &Sequence) -> Result<f64> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Score { banded: banded.clone(), seq: seq.clone(), reply: reply_tx })
            .map_err(|_| ApHmmError::Coordinator("XLA device thread is gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| ApHmmError::Coordinator("XLA device dropped the reply".into()))?
    }
}

/// The device thread plus its shutdown plumbing.
pub struct XlaDevice {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl XlaDevice {
    /// Spawn the device thread; fails fast if the artifacts are missing
    /// or do not compile.
    pub fn spawn(artifacts_dir: std::path::PathBuf) -> Result<XlaDevice> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::spawn(move || {
            let store = match ArtifactStore::load(&artifacts_dir) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Shutdown => break,
                    Request::BwSums { banded, seq, reply } => {
                        let result = XlaBandedEngine::for_shape(
                            &store,
                            banded.n,
                            banded.w,
                            banded.sigma,
                            seq.len(),
                        )
                        .and_then(|engine| engine.bw_sums(&banded, &seq));
                        let _ = reply.send(result);
                    }
                    Request::Score { banded, seq, reply } => {
                        let result = XlaBandedEngine::for_shape(
                            &store,
                            banded.n,
                            banded.w,
                            banded.sigma,
                            seq.len(),
                        )
                        .and_then(|engine| engine.score(&banded, &seq));
                        let _ = reply.send(result);
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| ApHmmError::Coordinator("XLA device thread died during init".into()))??;
        Ok(XlaDevice { tx, join: Some(join) })
    }

    /// A handle for workers.
    pub fn handle(&self) -> XlaHandle {
        XlaHandle { tx: self.tx.clone() }
    }
}

impl Drop for XlaDevice {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// The XLA device as an [`ExpectationEngine`]: every expectation pass
/// ships the banded encoding plus one read to the shared device thread
/// and accumulates the returned [`BandedBwSums`], exactly the way
/// ApHMM cores receive work from the host.  Maximization and the EM
/// schedule stay on the host in the generic training loop
/// (`train_with_engine`), so the device path composes with the same
/// pool, metrics and skip accounting as every other engine.
pub struct XlaEngine {
    /// The submit handle, behind a mutex so one engine instance can be
    /// shared by all E-step workers (`ExpectationEngine: Sync`).  The
    /// mutex is only touched once per worker: [`XlaEngine::make_scratch`]
    /// clones a private per-worker sender out of it, and every
    /// per-read call goes through that scratch handle lock-free.
    handle: Mutex<XlaHandle>,
}

impl XlaEngine {
    /// An engine submitting to `handle`'s device thread.
    pub fn new(handle: XlaHandle) -> XlaEngine {
        XlaEngine { handle: Mutex::new(handle) }
    }
}

impl ExpectationEngine for XlaEngine {
    type Prepared = BandedPhmm;
    /// A private submit handle per E-step worker.
    type Scratch = XlaHandle;
    type Acc = BandedAcc;

    fn name(&self) -> &'static str {
        "xla"
    }

    fn prepare(&self, phmm: &Phmm) -> Result<BandedPhmm> {
        phmm.to_banded()
    }

    fn make_scratch(&self, _phmm: &Phmm) -> XlaHandle {
        self.handle.lock().unwrap().clone()
    }

    fn make_acc(&self, phmm: &Phmm) -> BandedAcc {
        BandedAcc::new(phmm.n_states(), phmm.band_width(), phmm.sigma())
    }

    fn accumulate_read(
        &self,
        _phmm: &Phmm,
        prep: &BandedPhmm,
        read: &Sequence,
        _opts: &ForwardOptions,
        scratch: &mut XlaHandle,
        acc: &mut BandedAcc,
    ) -> Result<ReadStats> {
        let t0 = Instant::now();
        // Device failures (`ApHmmError::Runtime`) propagate out of the
        // training loop and are fatal in the coordinator; numerically
        // dead reads are skipped by the shared skip rule.
        let sums = scratch.bw_sums(prep, read)?;
        let elapsed = t0.elapsed().as_nanos();
        acc.loglik += sums.loglik as f64;
        acc.sums.add(&sums);
        acc.n_observations += 1;
        let t = read.len() as u64;
        let n = prep.n as u64;
        Ok(ReadStats {
            // The device fuses forward+backward in one artifact; charge
            // the round trip to the forward phase.
            forward_ns: elapsed,
            backward_update_ns: 0,
            filter_stats: FilterStats::default(),
            states_processed: n * t,
            edges_processed: n * prep.w as u64 * t.saturating_sub(1),
            timesteps: t,
            ..Default::default()
        })
    }

    fn merge(&self, into: &mut BandedAcc, from: &BandedAcc) {
        into.merge(from);
    }

    fn observations(&self, acc: &BandedAcc) -> (f64, u64) {
        (acc.loglik, acc.n_observations)
    }

    fn maximize(&self, phmm: &mut Phmm, acc: &BandedAcc) -> Result<()> {
        acc.maximize_into(phmm)
    }

    fn score(
        &self,
        _phmm: &Phmm,
        prep: &BandedPhmm,
        read: &Sequence,
        _opts: &ForwardOptions,
        scratch: &mut XlaHandle,
    ) -> Result<ScoreResult> {
        let loglik = scratch.score(prep, read)?;
        let t = read.len() as u64;
        let n = prep.n as u64;
        Ok(ScoreResult {
            loglik,
            filter_stats: FilterStats::default(),
            states_processed: n * t,
            edges_processed: n * prep.w as u64 * t.saturating_sub(1),
        })
    }
}
