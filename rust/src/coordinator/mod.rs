//! The L3 coordinator: multi-worker chunk-training orchestration.
//!
//! This is the deployment shape of the system: chunk-training jobs
//! **stream through a bounded [`JobQueue`]** (the same queue type the
//! serving layer runs on — the coordinator is one producer among many,
//! not a parallel code path) and are drained by worker participants of
//! one session-owned [`WorkerPool`].  Each job runs Baum-Welch training
//! (through the [`ExpectationEngine`] named by `cfg.train.engine`) plus
//! a Viterbi decode, and an optional shared **XLA device thread** plays
//! the accelerator's role — workers ship banded expectation requests to
//! it over a channel exactly the way ApHMM cores receive work from the
//! host (Supplemental S3's execution flow).  `tokio` is not in the
//! offline registry, so the runtime is std threads + channels, which
//! models the same structure.
//!
//! `CoordinatorConfig::queue_depth` is a real backpressure bound: the
//! producer admits at most that many pending jobs, and on a full queue
//! it **helps drain** (executes a queued job itself) instead of
//! blocking — the pool's caller-participates rule means helpers may
//! never join, so the producer must always be able to make progress
//! alone.  Queue gauges (depth high-water, producer block count) are
//! folded into [`Metrics`] at the end of the run.
//!
//! Chunk-level and E-step parallelism share the session pool: a chunk
//! worker that fans its E-step out (`cfg.train.n_workers > 1`) enlists
//! idle pool helpers and otherwise runs on its own thread, so the two
//! levels compose without oversubscription or deadlock (the ROADMAP's
//! "chunk-level + E-step thread-pool sharing" perf item).

mod metrics;
mod xla_device;

pub use metrics::{FailureCause, Metrics, MetricsSummary, StageSummary, StageTimes, TenantSummary, STAGES};
pub use xla_device::{XlaDevice, XlaEngine, XlaHandle};

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;
use std::time::Instant;

use crate::apps::train_chunk;
use crate::baumwelch::{train_with_engine, EngineKind, TrainConfig, TrainResult};
use crate::error::{ApHmmError, Result};
use crate::phmm::{EcDesignParams, Phmm};
use crate::pool::WorkerPool;
use crate::seq::Sequence;
use crate::server::{JobQueue, PushError};
use crate::viterbi::consensus;

/// Coordinator configuration.
///
/// Two levels of parallelism compose on one pool: `n_workers`
/// chunk-training participants, each of which may fan its per-chunk
/// E-step out across `train.n_workers` participants.  For many small
/// chunks, keep `train.n_workers = 1` and scale `n_workers`; reserve
/// the E-step workers for few/large chunks.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads (the paper's 4-core sweet spot).
    pub n_workers: usize,
    /// Bounded streaming-queue depth: at most this many jobs are
    /// admitted ahead of the workers; the producer helps drain when the
    /// queue is full (real backpressure, surfaced by the
    /// `queue_high_water`/`producer_blocks` gauges in
    /// [`MetricsSummary`]).
    pub queue_depth: usize,
    /// Training parameters; `train.engine` selects the compute backend
    /// ([`EngineKind::Xla`] routes through the shared device thread and
    /// requires [`CoordinatorConfig::artifacts_dir`]).
    pub train: TrainConfig,
    /// EC design parameters.
    pub design: EcDesignParams,
    /// Directory holding `manifest.txt` + `*.hlo.txt` for the XLA
    /// engine; ignored by the in-process engines.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// EM iterations on the XLA path (the device path runs a fixed
    /// iteration budget instead of `train.max_iters`/`tol`).
    pub xla_iters: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n_workers: 4,
            queue_depth: 16,
            train: TrainConfig::default(),
            design: EcDesignParams::default(),
            artifacts_dir: None,
            xla_iters: 2,
        }
    }
}

/// One chunk-training job.
#[derive(Clone, Debug)]
pub struct ChunkJob {
    /// Job identifier (chunk index).
    pub id: usize,
    /// Chunk reference sequence.
    pub reference: Sequence,
    /// Read segments mapped to the chunk.
    pub reads: Vec<Sequence>,
}

/// Result of one chunk job.
#[derive(Clone, Debug)]
pub struct ChunkOutcome {
    /// Job identifier.
    pub id: usize,
    /// Decoded consensus of the trained graph.
    pub consensus: Sequence,
    /// Mean per-read log-likelihood after training.
    pub mean_loglik: f64,
    /// Wall latency of the job (ns), measured on the executing worker
    /// from graph construction through consensus decode.
    pub latency_ns: u64,
    /// Worker that executed the job.
    pub worker: usize,
}

/// Run all jobs across the configured workers on a pool owned by this
/// session; outcomes are returned sorted by job id.  Failed jobs are
/// counted in the metrics and omitted from the output.
pub fn run_jobs(
    jobs: Vec<ChunkJob>,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
) -> Result<Vec<ChunkOutcome>> {
    // One pool per coordinator session, sized so the producer plus
    // every chunk worker can run, and each chunk's E-step fan-out can
    // still find helpers.
    let chunk_workers = cfg.n_workers.max(1);
    let estep_workers = cfg.train.n_workers.max(1);
    let helpers = chunk_workers + chunk_workers * (estep_workers - 1);
    let pool = WorkerPool::new(helpers);
    run_jobs_in(jobs, cfg, metrics, &pool)
}

/// [`run_jobs`] on a caller-owned [`WorkerPool`] (apps embedding the
/// coordinator share one pool across sessions).
pub fn run_jobs_in(
    jobs: Vec<ChunkJob>,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    pool: &WorkerPool,
) -> Result<Vec<ChunkOutcome>> {
    // `_xla_device` owns the device thread (joined on drop at the end
    // of this call); only the Sync `XlaEngine` wrapper is captured by
    // the worker closure.
    let (_xla_device, xla_engine): (Option<XlaDevice>, Option<XlaEngine>) =
        match cfg.train.engine {
            EngineKind::Xla => {
                let dir = cfg.artifacts_dir.clone().ok_or_else(|| {
                    ApHmmError::Config(
                        "EngineKind::Xla requires CoordinatorConfig::artifacts_dir".into(),
                    )
                })?;
                let device = XlaDevice::spawn(dir)?;
                let engine = XlaEngine::new(device.handle());
                (Some(device), Some(engine))
            }
            _ => (None, None),
        };

    let n_expected = jobs.len();
    let queue: JobQueue<ChunkJob> = JobQueue::new(cfg.queue_depth);
    let pending: Mutex<std::vec::IntoIter<ChunkJob>> = Mutex::new(jobs.into_iter());
    let outcomes: Mutex<Vec<ChunkOutcome>> = Mutex::new(Vec::with_capacity(n_expected));
    let fatal: Mutex<Option<ApHmmError>> = Mutex::new(None);

    // Execute one job on this participant and record its metrics.  On a
    // fatal (device) error the queue is aborted so the producer stops
    // admitting and the consumers drain out.  A *panicking* job is
    // contained at this boundary and becomes a by-cause failure: the
    // pool's own scope teardown is already panic-clean (helpers finish
    // before the payload is resumed), so the remaining jobs keep
    // running instead of the whole run tearing down.
    let run_job = |job: ChunkJob, worker_id: usize| {
        let t0 = Instant::now();
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_one(&job, cfg, xla_engine.as_ref(), worker_id, pool)
        }));
        match result {
            Ok(Ok((outcome, train))) => {
                metrics.record(t0.elapsed().as_nanos() as u64, train.timesteps, train.states_processed);
                if train.reads_skipped > 0 {
                    metrics.record_skipped_reads(train.reads_skipped);
                }
                metrics.record_train_progress(
                    train.epochs,
                    train.minibatches,
                    train.sequences_streamed,
                );
                outcomes.lock().unwrap().push(outcome);
            }
            Ok(Err(e)) => {
                metrics.record_failed_request(t0.elapsed().as_nanos() as u64, None);
                if matches!(e, ApHmmError::Runtime(_)) {
                    // Runtime (device) errors are fatal; numeric chunk
                    // failures are skipped.
                    *fatal.lock().unwrap() = Some(e);
                    queue.abort();
                }
            }
            Err(_payload) => {
                metrics.record_failed_request(
                    t0.elapsed().as_nanos() as u64,
                    Some(FailureCause::Panicked),
                );
            }
        }
    };

    // Participant 0 produces (streaming the job list through the
    // bounded queue); the others consume until the queue reports
    // exhaustion.  On a full queue the producer helps drain instead of
    // blocking, so progress never depends on a helper actually joining
    // (the pool enlists helpers opportunistically).
    // Closes the queue when the producer slot unwinds: without it, a
    // producer panic (e.g. a poisoned mutex after another participant
    // panicked) would leave the queue open and the consumers blocked in
    // `pop()` forever, deadlocking the scope teardown instead of
    // propagating the panic.
    struct CloseOnDrop<'a, T>(&'a JobQueue<T>);
    impl<T> Drop for CloseOnDrop<'_, T> {
        fn drop(&mut self) {
            self.0.close();
        }
    }

    pool.scope(cfg.n_workers.max(1) + 1, |slot| {
        if slot == 0 {
            let _close_guard = CloseOnDrop(&queue);
            loop {
                let next_job = pending.lock().unwrap().next();
                let Some(mut item) = next_job else { break };
                loop {
                    match queue.try_push(item) {
                        Ok(()) => break,
                        Err(PushError::Busy(back)) => {
                            item = back;
                            if let Some(job) = queue.try_pop() {
                                run_job(job, slot);
                            }
                        }
                        // Fatal abort elsewhere: stop producing.
                        Err(PushError::Closed(_)) => return,
                    }
                }
            }
            queue.close();
            while let Some(job) = queue.pop() {
                run_job(job, slot);
            }
        } else {
            while let Some(job) = queue.pop() {
                run_job(job, slot);
            }
        }
    });

    let qs = queue.stats();
    metrics.absorb_queue(qs.depth, qs.high_water, qs.producer_blocks);

    if let Some(e) = fatal.into_inner().unwrap() {
        return Err(e);
    }
    let mut outcomes = outcomes.into_inner().unwrap();
    outcomes.sort_by_key(|o| o.id);
    Ok(outcomes)
}

/// Execute one job on this worker.  Returns the outcome plus the full
/// training result, whose workload and schedule counters (timesteps,
/// states, skipped reads, epochs, minibatches, streamed sequences) feed
/// the coordinator metrics.
///
/// A chunk whose reads are all skipped trains zero iterations and is
/// emitted with `mean_loglik = -inf` and the untrained consensus —
/// uniform across every engine (the XLA path used to hard-error on
/// this; it now matches the native engines' semantics, and consumers
/// detect the case via the infinite `mean_loglik` plus the skipped-read
/// metrics).
fn run_one(
    job: &ChunkJob,
    cfg: &CoordinatorConfig,
    xla: Option<&XlaEngine>,
    worker: usize,
    pool: &WorkerPool,
) -> Result<(ChunkOutcome, TrainResult)> {
    let t0 = Instant::now();
    let (decoded, res) = match cfg.train.engine {
        EngineKind::Xla => {
            let engine = xla.ok_or_else(|| {
                ApHmmError::Coordinator("XLA engine requested but no device session".into())
            })?;
            // The device path runs a fixed iteration budget (matching
            // the accelerator's host schedule) instead of max_iters/tol.
            let xcfg = TrainConfig { max_iters: cfg.xla_iters.max(1), tol: 0.0, ..cfg.train };
            let mut graph = Phmm::error_correction(&job.reference, &cfg.design)?;
            let res = train_with_engine(engine, &mut graph, &job.reads, &xcfg, pool)?;
            (consensus(&graph)?.consensus, res)
        }
        // Native engines go through the shared chunk primitive (also
        // used by the batch corrector and the server's `Correct`
        // requests).
        _ => {
            let out = train_chunk(
                &job.reference,
                &job.reads,
                &cfg.design,
                crate::seq::DNA,
                &cfg.train,
                pool,
            )?;
            (out.consensus, out.train)
        }
    };
    let mean_loglik = res.loglik_history.last().copied().unwrap_or(f64::NEG_INFINITY);
    Ok((
        ChunkOutcome {
            id: job.id,
            consensus: decoded,
            mean_loglik,
            latency_ns: t0.elapsed().as_nanos() as u64,
            worker,
        },
        res,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    fn make_jobs(rng: &mut XorShift, n_jobs: usize, ref_len: usize) -> Vec<ChunkJob> {
        (0..n_jobs)
            .map(|id| {
                let reference =
                    Sequence::from_symbols(format!("c{id}"), testutil::random_seq(rng, ref_len, 4));
                let reads = (0..4)
                    .map(|i| {
                        simulate_read(rng, &reference, 0, ref_len, &ErrorProfile::pacbio(), i).seq
                    })
                    .collect();
                ChunkJob { id, reference, reads }
            })
            .collect()
    }

    #[test]
    fn all_jobs_complete_in_order() {
        let mut rng = XorShift::new(51);
        let jobs = make_jobs(&mut rng, 12, 60);
        let metrics = Metrics::default();
        let outcomes = run_jobs(jobs, &CoordinatorConfig::default(), &metrics).unwrap();
        assert_eq!(outcomes.len(), 12);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.id, i);
            assert!(!o.consensus.is_empty());
            assert!(o.latency_ns > 0, "job {i} has no measured latency");
        }
        let s = metrics.summary();
        assert_eq!(s.jobs_done, 12);
        assert_eq!(s.jobs_failed, 0);
        assert!(s.timesteps > 0);
    }

    #[test]
    fn single_worker_matches_multi_worker_consensus() {
        let mut rng = XorShift::new(52);
        let jobs = make_jobs(&mut rng, 6, 50);
        let m1 = Metrics::default();
        let m4 = Metrics::default();
        let one = run_jobs(
            jobs.clone(),
            &CoordinatorConfig { n_workers: 1, ..Default::default() },
            &m1,
        )
        .unwrap();
        let four = run_jobs(
            jobs,
            &CoordinatorConfig { n_workers: 4, ..Default::default() },
            &m4,
        )
        .unwrap();
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.consensus.data, b.consensus.data, "job {}", a.id);
        }
    }

    #[test]
    fn tiny_queue_applies_backpressure_without_deadlock() {
        let mut rng = XorShift::new(53);
        let jobs = make_jobs(&mut rng, 20, 40);
        let metrics = Metrics::default();
        let cfg = CoordinatorConfig { n_workers: 2, queue_depth: 1, ..Default::default() };
        let outcomes = run_jobs(jobs, &cfg, &metrics).unwrap();
        assert_eq!(outcomes.len(), 20);
        // The depth bound is real: never more than one job admitted
        // ahead of the workers, and the (instant) producer must have
        // been refused admission at least once by the (ms-scale)
        // training jobs.
        let s = metrics.summary();
        assert!(s.queue_high_water <= 1, "high water {}", s.queue_high_water);
        assert!(s.producer_blocks > 0, "queue_depth never exerted backpressure");
        assert_eq!(s.queue_depth, 0, "queue must drain by completion");
        assert!(s.latency_p50_ms > 0.0 && s.latency_p99_ms >= s.latency_p50_ms);
    }

    #[test]
    fn generous_queue_never_blocks_the_producer() {
        let mut rng = XorShift::new(59);
        let jobs = make_jobs(&mut rng, 6, 40);
        let metrics = Metrics::default();
        let cfg = CoordinatorConfig { n_workers: 2, queue_depth: 64, ..Default::default() };
        let outcomes = run_jobs(jobs, &cfg, &metrics).unwrap();
        assert_eq!(outcomes.len(), 6);
        let s = metrics.summary();
        assert_eq!(s.producer_blocks, 0);
        assert!(s.queue_high_water <= 6);
    }

    #[test]
    fn skipped_reads_surface_in_metrics() {
        let mut rng = XorShift::new(54);
        let mut jobs = make_jobs(&mut rng, 3, 50);
        // An empty read and an out-of-alphabet read are silently useless
        // to training; the coordinator must count them.
        jobs[0].reads.push(Sequence::from_symbols("empty", vec![]));
        jobs[1].reads.push(Sequence::from_symbols("bad", vec![0, 1, 200]));
        let metrics = Metrics::default();
        let outcomes = run_jobs(jobs, &CoordinatorConfig::default(), &metrics).unwrap();
        assert_eq!(outcomes.len(), 3);
        let s = metrics.summary();
        // Two skip events per EM iteration of their jobs — at least two.
        assert!(s.reads_skipped >= 2, "reads_skipped {}", s.reads_skipped);
    }

    #[test]
    fn estep_workers_compose_with_chunk_workers() {
        let mut rng = XorShift::new(55);
        let jobs = make_jobs(&mut rng, 4, 60);
        let m1 = Metrics::default();
        let m2 = Metrics::default();
        let sequential = run_jobs(
            jobs.clone(),
            &CoordinatorConfig { n_workers: 2, ..Default::default() },
            &m1,
        )
        .unwrap();
        let mut cfg = CoordinatorConfig { n_workers: 2, ..Default::default() };
        cfg.train.n_workers = 2;
        let threaded = run_jobs(jobs, &cfg, &m2).unwrap();
        assert_eq!(sequential.len(), threaded.len());
        for (a, b) in sequential.iter().zip(threaded.iter()) {
            assert_eq!(a.consensus.data, b.consensus.data, "job {}", a.id);
            assert_eq!(a.mean_loglik.to_bits(), b.mean_loglik.to_bits(), "job {}", a.id);
        }
    }

    #[test]
    fn banded_engine_runs_through_the_coordinator() {
        // Backend selection is pure configuration: the banded engine
        // trains every chunk through the same pool and metrics.
        let mut rng = XorShift::new(56);
        let jobs = make_jobs(&mut rng, 4, 50);
        let metrics = Metrics::default();
        let mut cfg = CoordinatorConfig { n_workers: 2, ..Default::default() };
        cfg.train.engine = EngineKind::Banded;
        let outcomes = run_jobs(jobs, &cfg, &metrics).unwrap();
        assert_eq!(outcomes.len(), 4);
        assert_eq!(metrics.summary().jobs_done, 4);
        for o in &outcomes {
            assert!(!o.consensus.is_empty());
            assert!(o.mean_loglik.is_finite());
            assert!(o.latency_ns > 0);
        }
    }

    #[test]
    fn xla_engine_without_artifacts_dir_is_a_config_error() {
        let mut rng = XorShift::new(57);
        let jobs = make_jobs(&mut rng, 1, 40);
        let metrics = Metrics::default();
        let mut cfg = CoordinatorConfig::default();
        cfg.train.engine = EngineKind::Xla;
        assert!(matches!(
            run_jobs(jobs, &cfg, &metrics),
            Err(ApHmmError::Config(_))
        ));
    }

    #[test]
    fn shared_session_pool_is_reusable() {
        let mut rng = XorShift::new(58);
        let pool = WorkerPool::new(3);
        let cfg = CoordinatorConfig { n_workers: 2, ..Default::default() };
        for round in 0..3 {
            let jobs = make_jobs(&mut rng, 5, 40);
            let metrics = Metrics::default();
            let outcomes = run_jobs_in(jobs, &cfg, &metrics, &pool).unwrap();
            assert_eq!(outcomes.len(), 5, "round {round}");
        }
    }

    #[test]
    fn empty_job_list_is_fine() {
        let metrics = Metrics::default();
        let outcomes = run_jobs(Vec::new(), &CoordinatorConfig::default(), &metrics).unwrap();
        assert!(outcomes.is_empty());
    }
}
