//! The L3 coordinator: multi-worker chunk-training orchestration.
//!
//! This is the deployment shape of the system: a leader thread feeds
//! chunk-training jobs through a bounded queue (backpressure), worker
//! threads run the Baum-Welch training + Viterbi decode per chunk, and
//! an optional shared **XLA device thread** plays the accelerator's
//! role — workers ship banded expectation requests to it over a channel
//! exactly the way ApHMM cores receive work from the host (Supplemental
//! S3's execution flow).  `tokio` is not in the offline registry, so the
//! runtime is std threads + `mpsc::sync_channel`, which models the same
//! structure.

mod metrics;
mod xla_device;

pub use metrics::{Metrics, MetricsSummary};
pub use xla_device::{XlaDevice, XlaHandle};

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::baumwelch::{train, TrainConfig};
use crate::error::{ApHmmError, Result};
use crate::phmm::{EcDesignParams, Phmm};
use crate::seq::Sequence;
use crate::viterbi::consensus;

/// Compute backend for chunk training.
#[derive(Clone, Debug)]
pub enum BackendKind {
    /// Native sparse Rust engine on each worker.
    Native,
    /// Expectation passes shipped to the shared XLA device thread
    /// (AOT artifacts via PJRT); reads must fit the artifact's T.
    Xla {
        /// Directory holding `manifest.txt` + `*.hlo.txt`.
        artifacts_dir: std::path::PathBuf,
    },
}

/// Coordinator configuration.
///
/// Two levels of parallelism compose: `n_workers` chunk-training
/// threads, each of which may fan its per-chunk E-step out across
/// `train.n_workers` threads (total peak threads ≈ the product).  For
/// many small chunks, keep `train.n_workers = 1` and scale `n_workers`;
/// reserve the E-step workers for few/large chunks.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads (the paper's 4-core sweet spot).
    pub n_workers: usize,
    /// Bounded queue depth (backpressure).
    pub queue_depth: usize,
    /// Training parameters.
    pub train: TrainConfig,
    /// EC design parameters.
    pub design: EcDesignParams,
    /// Compute backend.
    pub backend: BackendKind,
    /// EM iterations on the XLA path.
    pub xla_iters: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n_workers: 4,
            queue_depth: 16,
            train: TrainConfig::default(),
            design: EcDesignParams::default(),
            backend: BackendKind::Native,
            xla_iters: 2,
        }
    }
}

/// One chunk-training job.
#[derive(Clone, Debug)]
pub struct ChunkJob {
    /// Job identifier (chunk index).
    pub id: usize,
    /// Chunk reference sequence.
    pub reference: Sequence,
    /// Read segments mapped to the chunk.
    pub reads: Vec<Sequence>,
}

/// Result of one chunk job.
#[derive(Clone, Debug)]
pub struct ChunkOutcome {
    /// Job identifier.
    pub id: usize,
    /// Decoded consensus of the trained graph.
    pub consensus: Sequence,
    /// Mean per-read log-likelihood after training.
    pub mean_loglik: f64,
    /// Wall latency of the job (ns).
    pub latency_ns: u64,
    /// Worker that executed the job.
    pub worker: usize,
}

/// Run all jobs across the configured workers; outcomes are returned
/// sorted by job id.  Failed jobs are counted in the metrics and
/// omitted from the output.
pub fn run_jobs(
    jobs: Vec<ChunkJob>,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
) -> Result<Vec<ChunkOutcome>> {
    let n_workers = cfg.n_workers.max(1);
    let xla = match &cfg.backend {
        BackendKind::Native => None,
        BackendKind::Xla { artifacts_dir } => Some(XlaDevice::spawn(artifacts_dir.clone())?),
    };

    let (job_tx, job_rx) = mpsc::sync_channel::<ChunkJob>(cfg.queue_depth.max(1));
    let job_rx = Arc::new(std::sync::Mutex::new(job_rx));
    let (out_tx, out_rx) = mpsc::channel::<ChunkOutcome>();

    let worker_err: Arc<std::sync::Mutex<Option<ApHmmError>>> =
        Arc::new(std::sync::Mutex::new(None));

    std::thread::scope(|scope| -> Result<()> {
        for worker_id in 0..n_workers {
            let job_rx = Arc::clone(&job_rx);
            let out_tx = out_tx.clone();
            let cfg = cfg.clone();
            let xla_handle = xla.as_ref().map(|d| d.handle());
            let worker_err = Arc::clone(&worker_err);
            scope.spawn(move || {
                loop {
                    let job = {
                        let rx = job_rx.lock().unwrap();
                        rx.recv()
                    };
                    let Ok(job) = job else { break };
                    let t0 = Instant::now();
                    let result = run_one(&job, &cfg, xla_handle.as_ref(), worker_id);
                    match result {
                        Ok((outcome, timesteps, states, reads_skipped)) => {
                            metrics.record(t0.elapsed().as_nanos() as u64, timesteps, states);
                            if reads_skipped > 0 {
                                metrics.record_skipped_reads(reads_skipped);
                            }
                            let _ = out_tx.send(outcome);
                        }
                        Err(e) => {
                            metrics.record_failure();
                            if matches!(e, ApHmmError::Runtime(_)) {
                                // Runtime (device) errors are fatal;
                                // numeric chunk failures are skipped.
                                *worker_err.lock().unwrap() = Some(e);
                                break;
                            }
                        }
                    }
                }
            });
        }
        drop(out_tx);
        // Leader: feed jobs (blocks when the queue is full: backpressure).
        for job in jobs {
            job_tx.send(job).map_err(|_| {
                ApHmmError::Coordinator("all workers exited while jobs remain".into())
            })?;
        }
        drop(job_tx);
        Ok(())
    })?;

    if let Some(e) = worker_err.lock().unwrap().take() {
        return Err(e);
    }
    let mut outcomes: Vec<ChunkOutcome> = out_rx.try_iter().collect();
    outcomes.sort_by_key(|o| o.id);
    Ok(outcomes)
}

/// Execute one job on this worker.  Returns the outcome plus the
/// timestep/state workload counters and the number of skipped reads.
fn run_one(
    job: &ChunkJob,
    cfg: &CoordinatorConfig,
    xla: Option<&XlaHandle>,
    worker: usize,
) -> Result<(ChunkOutcome, u64, u64, u64)> {
    let mut graph = Phmm::error_correction(&job.reference, &cfg.design)?;
    let (mean_loglik, timesteps, states, reads_skipped) = match xla {
        None => {
            let res = train(&mut graph, &job.reads, &cfg.train)?;
            (
                res.loglik_history.last().copied().unwrap_or(f64::NEG_INFINITY),
                res.timesteps,
                res.states_processed,
                res.reads_skipped,
            )
        }
        Some(handle) => {
            let stats = xla_device::train_via_xla(handle, &mut graph, &job.reads, cfg.xla_iters)?;
            (stats.mean_loglik, stats.timesteps, stats.states, stats.reads_skipped)
        }
    };
    let decoded = consensus(&graph)?;
    Ok((
        ChunkOutcome {
            id: job.id,
            consensus: decoded.consensus,
            mean_loglik,
            latency_ns: 0,
            worker,
        },
        timesteps,
        states,
        reads_skipped,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    fn make_jobs(rng: &mut XorShift, n_jobs: usize, ref_len: usize) -> Vec<ChunkJob> {
        (0..n_jobs)
            .map(|id| {
                let reference =
                    Sequence::from_symbols(format!("c{id}"), testutil::random_seq(rng, ref_len, 4));
                let reads = (0..4)
                    .map(|i| {
                        simulate_read(rng, &reference, 0, ref_len, &ErrorProfile::pacbio(), i).seq
                    })
                    .collect();
                ChunkJob { id, reference, reads }
            })
            .collect()
    }

    #[test]
    fn all_jobs_complete_in_order() {
        let mut rng = XorShift::new(51);
        let jobs = make_jobs(&mut rng, 12, 60);
        let metrics = Metrics::default();
        let outcomes = run_jobs(jobs, &CoordinatorConfig::default(), &metrics).unwrap();
        assert_eq!(outcomes.len(), 12);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.id, i);
            assert!(!o.consensus.is_empty());
        }
        let s = metrics.summary(1.0);
        assert_eq!(s.jobs_done, 12);
        assert_eq!(s.jobs_failed, 0);
        assert!(s.timesteps > 0);
    }

    #[test]
    fn single_worker_matches_multi_worker_consensus() {
        let mut rng = XorShift::new(52);
        let jobs = make_jobs(&mut rng, 6, 50);
        let m1 = Metrics::default();
        let m4 = Metrics::default();
        let one = run_jobs(
            jobs.clone(),
            &CoordinatorConfig { n_workers: 1, ..Default::default() },
            &m1,
        )
        .unwrap();
        let four = run_jobs(
            jobs,
            &CoordinatorConfig { n_workers: 4, ..Default::default() },
            &m4,
        )
        .unwrap();
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.consensus.data, b.consensus.data, "job {}", a.id);
        }
    }

    #[test]
    fn tiny_queue_applies_backpressure_without_deadlock() {
        let mut rng = XorShift::new(53);
        let jobs = make_jobs(&mut rng, 20, 40);
        let metrics = Metrics::default();
        let cfg = CoordinatorConfig { n_workers: 2, queue_depth: 1, ..Default::default() };
        let outcomes = run_jobs(jobs, &cfg, &metrics).unwrap();
        assert_eq!(outcomes.len(), 20);
    }

    #[test]
    fn skipped_reads_surface_in_metrics() {
        let mut rng = XorShift::new(54);
        let mut jobs = make_jobs(&mut rng, 3, 50);
        // An empty read and an out-of-alphabet read are silently useless
        // to training; the coordinator must count them.
        jobs[0].reads.push(Sequence::from_symbols("empty", vec![]));
        jobs[1].reads.push(Sequence::from_symbols("bad", vec![0, 1, 200]));
        let metrics = Metrics::default();
        let outcomes = run_jobs(jobs, &CoordinatorConfig::default(), &metrics).unwrap();
        assert_eq!(outcomes.len(), 3);
        let s = metrics.summary(1.0);
        // Two skip events per EM iteration of their jobs — at least two.
        assert!(s.reads_skipped >= 2, "reads_skipped {}", s.reads_skipped);
    }

    #[test]
    fn estep_workers_compose_with_chunk_workers() {
        let mut rng = XorShift::new(55);
        let jobs = make_jobs(&mut rng, 4, 60);
        let m1 = Metrics::default();
        let m2 = Metrics::default();
        let sequential = run_jobs(
            jobs.clone(),
            &CoordinatorConfig { n_workers: 2, ..Default::default() },
            &m1,
        )
        .unwrap();
        let mut cfg = CoordinatorConfig { n_workers: 2, ..Default::default() };
        cfg.train.n_workers = 2;
        let threaded = run_jobs(jobs, &cfg, &m2).unwrap();
        assert_eq!(sequential.len(), threaded.len());
        for (a, b) in sequential.iter().zip(threaded.iter()) {
            assert_eq!(a.consensus.data, b.consensus.data, "job {}", a.id);
            assert_eq!(a.mean_loglik.to_bits(), b.mean_loglik.to_bits(), "job {}", a.id);
        }
    }

    #[test]
    fn empty_job_list_is_fine() {
        let metrics = Metrics::default();
        let outcomes = run_jobs(Vec::new(), &CoordinatorConfig::default(), &metrics).unwrap();
        assert!(outcomes.is_empty());
    }
}
