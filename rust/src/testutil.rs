//! Property-testing helpers.
//!
//! `proptest` is not in the offline registry, so this module provides a
//! small deterministic property harness over [`crate::sim::XorShift`]:
//! run a closure across many seeded random cases and report the failing
//! seed on panic, which is all the shrinking we need for numeric code
//! (re-run the single seed to reproduce).

use crate::sim::XorShift;

/// Run `body` for `cases` deterministic seeds.  On failure, the panic
/// message names the seed so the case can be replayed in isolation.
pub fn check(cases: usize, mut body: impl FnMut(&mut XorShift)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = XorShift::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two floats agree within relative tolerance `rtol` plus absolute
/// floor `atol`.
#[track_caller]
pub fn assert_close(a: f64, b: f64, rtol: f64, atol: f64) {
    let diff = (a - b).abs();
    let bound = atol + rtol * a.abs().max(b.abs());
    assert!(diff <= bound, "assert_close failed: {a} vs {b} (diff {diff} > {bound})");
}

/// Assert two slices agree elementwise within tolerance.
#[track_caller]
pub fn assert_all_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let diff = (x - y).abs();
        let bound = atol + rtol * x.abs().max(y.abs());
        assert!(diff <= bound, "assert_all_close failed at [{i}]: {x} vs {y} (diff {diff} > {bound})");
    }
}

/// Random probability vector of length `n` (sums to 1, all > 0).
pub fn random_dist(rng: &mut XorShift, n: usize) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| rng.next_f64() + 1e-3).collect();
    let s: f64 = v.iter().sum();
    v.iter_mut().for_each(|x| *x /= s);
    v
}

/// Random encoded sequence over an alphabet of size `sigma`.
pub fn random_seq(rng: &mut XorShift, len: usize, sigma: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(sigma) as u8).collect()
}

/// A structurally near-dense banded chain [`Phmm`]: every state reaches
/// its next three successors (band 4 = one `TILE_LANES` tile width,
/// occupancy ≈ 0.69 ≥ `TILE_MIN_OCCUPANCY`), uniform DNA emissions,
/// all start mass on state 0 — the regime where the adaptive gather
/// policy's occupancy gate admits the dense-tile kernel, unlike the
/// default EC design (in-degree ≈ 7 in a 25-wide band).  Shared by the
/// `baumwelch::sparse` dispatch tests and the hotpath bench so both pin
/// the same graph.  Forward passes survive any read shorter than `n`
/// (the minimum hop is one state per timestep).
pub fn dense_band_phmm(n: usize) -> crate::phmm::Phmm {
    use crate::phmm::{Phmm, PhmmDesign, StateKind};
    use crate::seq::DNA;
    let mut out_ptr = vec![0u32];
    let mut out_to = Vec::new();
    let mut out_prob = Vec::new();
    for i in 0..n {
        let targets: Vec<usize> = (i + 1..n.min(i + 4)).collect();
        if !targets.is_empty() {
            let p = 1.0 / targets.len() as f32;
            for &t in &targets {
                out_to.push(t as u32);
                out_prob.push(p);
            }
        }
        out_ptr.push(out_to.len() as u32);
    }
    let mut f_init = vec![0.0f32; n];
    f_init[0] = 1.0;
    let g = Phmm {
        design: PhmmDesign::ErrorCorrection,
        alphabet: DNA,
        kinds: vec![StateKind::Match; n],
        position: (0..n as u32).collect(),
        out_ptr,
        out_to,
        out_prob,
        emissions: vec![0.25; n * 4],
        f_init,
    };
    g.validate().expect("dense band graph must validate");
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property failed at case 0")]
    fn check_reports_seed() {
        check(5, |_| panic!("boom"));
    }

    #[test]
    fn random_dist_normalized() {
        check(10, |rng| {
            let d = random_dist(rng, 17);
            assert_close(d.iter().sum::<f64>(), 1.0, 1e-12, 1e-12);
            assert!(d.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    #[should_panic(expected = "assert_close failed")]
    fn assert_close_detects_mismatch() {
        assert_close(1.0, 1.1, 1e-6, 1e-9);
    }
}
