//! Artifact manifest parsing.
//!
//! `artifacts/manifest.txt` (written by `python/compile/aot.py`) lists
//! every lowered executable with its entry point and static shapes:
//!
//! ```text
//! ec_bw_n512_w32_t128 entry=baum_welch_sums n=512 w=16 sigma=4 t=128 args=... results=5
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{ApHmmError, Result};

/// One artifact's static description.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Artifact name (file stem of the `.hlo.txt`).
    pub name: String,
    /// L2 entry point (`forward_scores` or `baum_welch_sums`).
    pub entry: String,
    /// States N.
    pub n: usize,
    /// Band width W.
    pub w: usize,
    /// Alphabet size Σ.
    pub sigma: usize,
    /// Static chunk length T.
    pub t: usize,
    /// Number of results in the output tuple.
    pub results: usize,
    /// Path of the HLO text file.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    specs: HashMap<String, ArtifactSpec>,
}

impl ArtifactManifest {
    /// Parse `manifest.txt` in `dir`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text, dir, &path.display().to_string())
    }

    /// Parse manifest text (tests).
    pub fn parse(text: &str, dir: &Path, origin: &str) -> Result<ArtifactManifest> {
        let mut specs = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let err =
                |m: String| ApHmmError::Parse { path: origin.into(), msg: format!("line {}: {m}", lineno + 1) };
            let mut it = line.split_whitespace();
            let name = it.next().ok_or_else(|| err("missing name".into()))?.to_string();
            let mut fields: HashMap<&str, &str> = HashMap::new();
            for tok in it {
                if let Some((k, v)) = tok.split_once('=') {
                    fields.insert(k, v);
                }
            }
            let get_usize = |k: &str| -> Result<usize> {
                fields
                    .get(k)
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| err(format!("missing/bad field {k}")))
            };
            let spec = ArtifactSpec {
                path: dir.join(format!("{name}.hlo.txt")),
                entry: fields
                    .get("entry")
                    .ok_or_else(|| err("missing entry".into()))?
                    .to_string(),
                n: get_usize("n")?,
                w: get_usize("w")?,
                sigma: get_usize("sigma")?,
                t: get_usize("t")?,
                results: get_usize("results")?,
                name,
            };
            specs.insert(spec.name.clone(), spec);
        }
        Ok(ArtifactManifest { specs })
    }

    /// Look up a spec by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// All specs, name-sorted.
    pub fn specs(&self) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> = self.specs.values().collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Find the smallest artifact with `entry` that fits the given
    /// problem shape (used by the coordinator's backend selection).
    pub fn find_fitting(
        &self,
        entry: &str,
        n: usize,
        w: usize,
        sigma: usize,
        t: usize,
    ) -> Option<&ArtifactSpec> {
        self.specs
            .values()
            .filter(|s| s.entry == entry && s.n >= n && s.w >= w && s.sigma == sigma && s.t >= t)
            .min_by_key(|s| s.n * s.w * s.t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
ec_bw_n512_w32_t128 entry=baum_welch_sums n=512 w=16 sigma=4 t=128 args=x results=5
pro_fwd_n384_w8_t128 entry=forward_scores n=384 w=8 sigma=20 t=128 args=x results=1
";

    #[test]
    fn parses_fields() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a"), "mem").unwrap();
        let s = m.get("ec_bw_n512_w32_t128").unwrap();
        assert_eq!(s.entry, "baum_welch_sums");
        assert_eq!((s.n, s.w, s.sigma, s.t, s.results), (512, 16, 4, 128, 5));
        assert_eq!(s.path, Path::new("/tmp/a/ec_bw_n512_w32_t128.hlo.txt"));
    }

    #[test]
    fn find_fitting_respects_shape() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp"), "mem").unwrap();
        assert!(m.find_fitting("baum_welch_sums", 300, 12, 4, 100).is_some());
        assert!(m.find_fitting("baum_welch_sums", 600, 12, 4, 100).is_none());
        assert!(m.find_fitting("baum_welch_sums", 300, 20, 4, 100).is_none());
        assert!(m.find_fitting("forward_scores", 300, 8, 20, 128).is_some());
        assert!(m.find_fitting("forward_scores", 300, 8, 20, 200).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ArtifactManifest::parse("name entry=e n=bad", Path::new("/"), "mem").is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            assert!(m.get("ec_bw_n512_w32_t128").is_some());
        }
    }
}
