//! API-compatible stubs for the PJRT executor (default build).
//!
//! The real executor (`executor.rs`) links against the `xla` crate (PJRT
//! C API), which is not available in the offline build environment.  The
//! default build therefore compiles this stub instead: the same public
//! surface, but [`ArtifactStore::load`] always fails with
//! [`ApHmmError::Runtime`], so every consumer — the CLI `runtime`
//! subcommand, the coordinator's XLA device thread, the parity tests —
//! compiles unchanged and degrades gracefully at runtime.  Build with
//! `--features pjrt` (plus a vendored `xla` crate) for real execution;
//! the bare `xla` feature keeps these stubs so the feature-gated engine
//! surface compiles offline.

use std::path::Path;

use crate::baumwelch::BandedBwSums;
use crate::error::{ApHmmError, Result};
use crate::phmm::BandedPhmm;
use crate::seq::Sequence;

use super::artifacts::ArtifactSpec;

fn unavailable(what: &str) -> ApHmmError {
    ApHmmError::Runtime(format!(
        "{what}: built without the `pjrt` feature (PJRT runtime unavailable)"
    ))
}

/// Stub artifact store; [`ArtifactStore::load`] always errors.
pub struct ArtifactStore {
    _priv: (),
}

impl ArtifactStore {
    /// Always fails in the default build.
    pub fn load(dir: &Path) -> Result<ArtifactStore> {
        Err(unavailable(&format!("cannot load artifacts from {}", dir.display())))
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "stub".into()
    }

    /// Names of the compiled artifacts (always empty).
    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }

    /// Spec of a compiled artifact (always `None`).
    pub fn spec(&self, _name: &str) -> Option<&ArtifactSpec> {
        None
    }
}

/// Stub engine mirroring `XlaBandedEngine`'s surface.
pub struct XlaBandedEngine<'a> {
    _store: &'a ArtifactStore,
    /// Artifact with entry `baum_welch_sums` (None = scoring only).
    pub bw_artifact: Option<String>,
    /// Artifact with entry `forward_scores`.
    pub fwd_artifact: Option<String>,
}

impl<'a> XlaBandedEngine<'a> {
    /// Always fails in the default build.
    pub fn for_shape(
        _store: &'a ArtifactStore,
        _n: usize,
        _w: usize,
        _sigma: usize,
        _t: usize,
    ) -> Result<XlaBandedEngine<'a>> {
        Err(unavailable("XlaBandedEngine::for_shape"))
    }

    /// Always fails in the default build.
    pub fn score(&self, _banded: &BandedPhmm, _seq: &Sequence) -> Result<f64> {
        Err(unavailable("XlaBandedEngine::score"))
    }

    /// Always fails in the default build.
    pub fn bw_sums(&self, _banded: &BandedPhmm, _seq: &Sequence) -> Result<BandedBwSums> {
        Err(unavailable("XlaBandedEngine::bw_sums"))
    }
}
