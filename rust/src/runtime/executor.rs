//! PJRT execution of the AOT artifacts.

use std::collections::HashMap;
use std::path::Path;

use crate::baumwelch::BandedBwSums;
use crate::error::{ApHmmError, Result};
use crate::phmm::BandedPhmm;
use crate::seq::Sequence;

use super::artifacts::{ArtifactManifest, ArtifactSpec};

/// A compiled artifact.
struct Compiled {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Loads and compiles every artifact in a directory; executions are
/// dispatched by artifact name.  Compilation happens once at startup
/// (`make artifacts` is the only place Python runs).
pub struct ArtifactStore {
    client: xla::PjRtClient,
    compiled: HashMap<String, Compiled>,
}

impl ArtifactStore {
    /// Open the PJRT CPU client and compile all artifacts in `dir`.
    pub fn load(dir: &Path) -> Result<ArtifactStore> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut compiled = HashMap::new();
        for spec in manifest.specs() {
            let proto = xla::HloModuleProto::from_text_file(&spec.path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            compiled.insert(spec.name.clone(), Compiled { spec: spec.clone(), exe });
        }
        Ok(ArtifactStore { client, compiled })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of the compiled artifacts.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.compiled.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Spec of a compiled artifact.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.compiled.get(name).map(|c| &c.spec)
    }

    /// Execute `name` on a banded pHMM and a (padded) sequence.
    ///
    /// The graph is padded to the artifact's static `(N, W)`; the
    /// sequence is padded to `T` with the true length passed in the
    /// `length` scalar (the L2 model masks padded timesteps).
    fn execute(
        &self,
        name: &str,
        banded: &BandedPhmm,
        seq: &Sequence,
    ) -> Result<(Vec<xla::Literal>, usize, usize)> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| ApHmmError::Runtime(format!("unknown artifact {name:?}")))?;
        let spec = &c.spec;
        if seq.len() > spec.t {
            return Err(ApHmmError::Runtime(format!(
                "sequence length {} exceeds artifact T={}",
                seq.len(),
                spec.t
            )));
        }
        if banded.sigma != spec.sigma {
            return Err(ApHmmError::Runtime(format!(
                "alphabet {} != artifact sigma {}",
                banded.sigma, spec.sigma
            )));
        }
        let padded;
        let b = if banded.n == spec.n && banded.w == spec.w {
            banded
        } else {
            padded = banded.pad_to(spec.n, spec.w)?;
            &padded
        };
        let a_band = xla::Literal::vec1(&b.a_band).reshape(&[spec.n as i64, spec.w as i64])?;
        let emit = xla::Literal::vec1(&b.emit).reshape(&[spec.n as i64, spec.sigma as i64])?;
        let mut seq_pad = vec![0i32; spec.t];
        for (i, &s) in seq.data.iter().enumerate() {
            seq_pad[i] = s as i32;
        }
        let seq_lit = xla::Literal::vec1(&seq_pad);
        let f_init = xla::Literal::vec1(&b.f_init);
        let length = xla::Literal::scalar(seq.len() as i32);

        let result = c.exe.execute::<xla::Literal>(&[a_band, emit, seq_lit, f_init, length])?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != spec.results {
            return Err(ApHmmError::Runtime(format!(
                "artifact {name} returned {} results, manifest says {}",
                parts.len(),
                spec.results
            )));
        }
        Ok((parts, spec.n, spec.w))
    }
}

/// Drop-in XLA replacement for [`crate::baumwelch::BandedEngine`].
///
/// Holds the store plus the artifact names to dispatch to; results are
/// truncated back from the artifact's padded static shape to the
/// caller's `(N, W)`.
pub struct XlaBandedEngine<'a> {
    store: &'a ArtifactStore,
    /// Artifact with entry `baum_welch_sums` (None = scoring only).
    pub bw_artifact: Option<String>,
    /// Artifact with entry `forward_scores`.
    pub fwd_artifact: Option<String>,
}

impl<'a> XlaBandedEngine<'a> {
    /// Pick artifacts that fit the given problem shape.
    pub fn for_shape(
        store: &'a ArtifactStore,
        n: usize,
        w: usize,
        sigma: usize,
        t: usize,
    ) -> Result<XlaBandedEngine<'a>> {
        let manifest_fit = |entry: &str| {
            let mut best: Option<&ArtifactSpec> = None;
            for name in store.names() {
                let s = store.spec(name).unwrap();
                if s.entry == entry && s.n >= n && s.w >= w && s.sigma == sigma && s.t >= t {
                    best = match best {
                        Some(b) if b.n * b.w * b.t <= s.n * s.w * s.t => Some(b),
                        _ => Some(s),
                    };
                }
            }
            best.map(|s| s.name.clone())
        };
        let bw = manifest_fit("baum_welch_sums");
        let fwd = manifest_fit("forward_scores");
        if bw.is_none() && fwd.is_none() {
            return Err(ApHmmError::Runtime(format!(
                "no artifact fits shape n={n} w={w} sigma={sigma} t={t}"
            )));
        }
        Ok(XlaBandedEngine { store, bw_artifact: bw, fwd_artifact: fwd })
    }

    /// Forward-only log-likelihood (mirrors `BandedEngine::score`).
    pub fn score(&self, banded: &BandedPhmm, seq: &Sequence) -> Result<f64> {
        let name = self
            .fwd_artifact
            .as_ref()
            .ok_or_else(|| ApHmmError::Runtime("no forward artifact".into()))?;
        let (parts, _, _) = self.store.execute(name, banded, seq)?;
        Ok(parts[0].to_vec::<f32>()?[0] as f64)
    }

    /// Full expectation pass (mirrors `BandedEngine::bw_sums`).
    pub fn bw_sums(&self, banded: &BandedPhmm, seq: &Sequence) -> Result<BandedBwSums> {
        let name = self
            .bw_artifact
            .as_ref()
            .ok_or_else(|| ApHmmError::Runtime("no baum_welch artifact".into()))?;
        let (parts, n_pad, w_pad) = self.store.execute(name, banded, seq)?;
        let xi_flat = parts[0].to_vec::<f32>()?;
        let trans_den_p = parts[1].to_vec::<f32>()?;
        let e_num_p = parts[2].to_vec::<f32>()?;
        let gamma_den_p = parts[3].to_vec::<f32>()?;
        let loglik = parts[4].to_vec::<f32>()?[0];

        // Truncate from the artifact's padded (n_pad, w_pad) back to the
        // caller's (n, w).
        let (n, w, sigma) = (banded.n, banded.w, banded.sigma);
        let mut sums = BandedBwSums::zeros(n, w, sigma);
        for j in 0..n {
            sums.xi_band[j * w..(j + 1) * w]
                .copy_from_slice(&xi_flat[j * w_pad..j * w_pad + w]);
        }
        sums.trans_den.copy_from_slice(&trans_den_p[..n]);
        sums.e_num.copy_from_slice(&e_num_p[..n * sigma]);
        sums.gamma_den.copy_from_slice(&gamma_den_p[..n]);
        sums.loglik = loglik;
        let _ = n_pad;
        Ok(sums)
    }
}
