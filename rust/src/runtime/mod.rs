//! PJRT runtime — executes the AOT-compiled L2/L1 artifacts from Rust.
//!
//! `make artifacts` lowers the JAX Baum-Welch model to HLO *text* (see
//! `python/compile/aot.py` for why text, not serialized protos); this
//! module loads those files through the `xla` crate (PJRT C API, CPU
//! plugin), compiles each once, and exposes a [`XlaBandedEngine`] that
//! is a drop-in replacement for the native
//! [`crate::baumwelch::BandedEngine`] — same banded inputs, same raw
//! update sums out.  Python never runs at request time.

mod artifacts;
#[cfg(feature = "pjrt")]
mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
mod executor;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use executor::{ArtifactStore, XlaBandedEngine};
