//! # ApHMM — Accelerating Profile Hidden Markov Models
//!
//! Full-system reproduction of *ApHMM: Accelerating Profile Hidden Markov
//! Models for Fast and Energy-Efficient Genome Analysis* (Firtina et al.,
//! 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the deployable system: pHMM construction for
//!   the traditional and error-correction designs, a complete sparse
//!   Baum-Welch engine with sort-based and histogram state filters —
//!   its hot path built on memoized per-symbol fused-coefficient
//!   tables (the software analogue of the paper's §4.2–4.3 on-chip
//!   memoization; see `baumwelch/README.md`), a score-only
//!   constant-memory forward for inference, and a deterministic
//!   block-parallel batch E-step — all reachable behind the pluggable
//!   [`baumwelch::ExpectationEngine`] trait (sparse / banded /
//!   reference / XLA backends selected by
//!   [`baumwelch::EngineKind`], parallelism drawn from one shared
//!   [`pool::WorkerPool`]) — Viterbi consensus decoding, the
//!   three end-to-end applications (error correction, protein family
//!   search, multiple sequence alignment), simulation substrates
//!   (genomes, long reads, protein families), a minimizer read mapper,
//!   a multi-threaded training coordinator streaming its jobs through a
//!   bounded queue, a multi-tenant [`server`] (persistent job queue +
//!   cross-request cache of frozen coefficient tables + line protocol
//!   over stdin/TCP), and the ApHMM accelerator
//!   performance/energy/area model that regenerates every table and
//!   figure of the paper.
//! * **L2/L1 (python/, build time only)** — the banded Baum-Welch
//!   computation in JAX with Pallas kernels, AOT-lowered to HLO text.
//! * **Runtime** — [`runtime`] loads those artifacts through the PJRT C
//!   API (`xla` crate) and executes them from the Rust hot path; Python
//!   never runs at request time.  The PJRT backend is gated behind the
//!   `xla` cargo feature; the default (dependency-free) build ships
//!   API-compatible stubs that fail gracefully at runtime.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod accel;
pub mod apps;
pub mod baumwelch;
pub mod cancel;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod failpoint;
pub mod io;
pub mod mapper;
pub mod obs;
pub mod phmm;
pub mod pool;
pub mod runtime;
pub mod seq;
pub mod server;
pub mod sim;
pub mod testutil;
pub mod viterbi;

pub use error::{ApHmmError, Result};

/// Mark a named fault-injection site (see the [`failpoint`] module).
///
/// Statement position only.  Two forms:
///
/// * `failpoint!("site")` — evaluates the site for its side effects
///   (`Panic` / `Sleep` actions); an armed `Error` action is ignored.
/// * `failpoint!("site", mapper)` — additionally, if an `Error` action
///   fires, `return Err(mapper(message))` from the enclosing function.
///
/// Without the `failpoints` cargo feature both forms expand to an
/// empty block: the sites cost nothing and pull in no code.
#[macro_export]
macro_rules! failpoint {
    ($name:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            let _ = $crate::failpoint::eval($name);
        }
    }};
    ($name:expr, $mapper:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            if let Some(__fp_msg) = $crate::failpoint::eval($name) {
                return Err($mapper(__fp_msg));
            }
        }
    }};
}
