//! Encoded biological sequences.

use super::Alphabet;
use crate::error::Result;

/// A named, alphabet-encoded sequence (symbols, not ASCII).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Sequence {
    /// Record identifier (FASTA/FASTQ header token).
    pub id: String,
    /// Encoded symbols, each `< alphabet.size()`.
    pub data: Vec<u8>,
}

impl Sequence {
    /// Build from an ASCII string, encoding through `alphabet`.
    pub fn from_str(id: impl Into<String>, s: &str, alphabet: Alphabet) -> Result<Self> {
        Ok(Sequence { id: id.into(), data: alphabet.encode_str(s)? })
    }

    /// Build directly from encoded symbols.
    pub fn from_symbols(id: impl Into<String>, data: Vec<u8>) -> Self {
        Sequence { id: id.into(), data }
    }

    /// Sequence length in symbols.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the sequence has no symbols.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Decode back to ASCII.
    pub fn to_ascii(&self, alphabet: Alphabet) -> String {
        alphabet.decode_all(&self.data)
    }

    /// Borrow a subrange as a new sequence (used by the chunker).
    pub fn slice(&self, start: usize, end: usize) -> Sequence {
        Sequence {
            id: format!("{}:{}-{}", self.id, start, end),
            data: self.data[start..end.min(self.data.len())].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::DNA;

    #[test]
    fn roundtrip_and_slice() {
        let s = Sequence::from_str("r1", "ACGTACGT", DNA).unwrap();
        assert_eq!(s.len(), 8);
        assert_eq!(s.to_ascii(DNA), "ACGTACGT");
        let sub = s.slice(2, 6);
        assert_eq!(sub.to_ascii(DNA), "GTAC");
        assert_eq!(sub.id, "r1:2-6");
    }

    #[test]
    fn slice_clamps_end() {
        let s = Sequence::from_str("r", "ACGT", DNA).unwrap();
        assert_eq!(s.slice(1, 100).to_ascii(DNA), "CGT");
    }
}
