//! Biological sequences and alphabets.

mod alphabet;
mod sequence;

pub use alphabet::{Alphabet, DNA, PROTEIN};
pub use sequence::Sequence;
