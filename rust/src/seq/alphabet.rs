//! Alphabets for biological sequences (DNA: Σ=4, protein: Σ=20).
//!
//! ApHMM's microarchitecture is parameterized by the alphabet size `nΣ`
//! (§4.3: "Our microarchitecture design is flexible such that it allows
//! defining nΣ as a parameter"); everything downstream of this module
//! treats Σ as a runtime value.

use crate::error::{ApHmmError, Result};

/// An immutable symbol alphabet with O(1) encode/decode.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Alphabet {
    name: &'static str,
    chars: &'static [u8],
}

/// The DNA alphabet (A, C, G, T).
pub const DNA: Alphabet = Alphabet { name: "dna", chars: b"ACGT" };

/// The 20-letter amino-acid alphabet.
pub const PROTEIN: Alphabet = Alphabet { name: "protein", chars: b"ACDEFGHIKLMNPQRSTVWY" };

impl Alphabet {
    /// Number of symbols (`nΣ`): 4 for DNA, 20 for protein.
    #[inline]
    pub fn size(&self) -> usize {
        self.chars.len()
    }

    /// Human-readable name, used in config files and profile headers.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Look up an alphabet by its `name()`.
    pub fn by_name(name: &str) -> Result<Alphabet> {
        match name {
            "dna" => Ok(DNA),
            "protein" => Ok(PROTEIN),
            other => Err(ApHmmError::Config(format!("unknown alphabet {other:?}"))),
        }
    }

    /// Encode one ASCII character to its symbol index (case-insensitive).
    #[inline]
    pub fn encode(&self, ch: u8) -> Result<u8> {
        let up = ch.to_ascii_uppercase();
        self.chars
            .iter()
            .position(|&c| c == up)
            .map(|i| i as u8)
            .ok_or(ApHmmError::InvalidCharacter { ch: ch as char, alphabet: self.name })
    }

    /// Decode a symbol index back to its ASCII character.
    #[inline]
    pub fn decode(&self, sym: u8) -> u8 {
        self.chars[sym as usize]
    }

    /// Encode a full ASCII string.
    pub fn encode_str(&self, s: &str) -> Result<Vec<u8>> {
        s.bytes().map(|b| self.encode(b)).collect()
    }

    /// Decode a symbol slice into an ASCII string.
    pub fn decode_all(&self, syms: &[u8]) -> String {
        syms.iter().map(|&s| self.decode(s) as char).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dna_roundtrip() {
        let enc = DNA.encode_str("ACGTacgt").unwrap();
        assert_eq!(enc, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        assert_eq!(DNA.decode_all(&enc), "ACGTACGT");
    }

    #[test]
    fn protein_size() {
        assert_eq!(PROTEIN.size(), 20);
        assert_eq!(DNA.size(), 4);
    }

    #[test]
    fn protein_roundtrip_all() {
        let all = "ACDEFGHIKLMNPQRSTVWY";
        let enc = PROTEIN.encode_str(all).unwrap();
        assert_eq!(enc.len(), 20);
        assert_eq!(PROTEIN.decode_all(&enc), all);
    }

    #[test]
    fn invalid_char_rejected() {
        assert!(DNA.encode(b'N').is_err());
        assert!(PROTEIN.encode(b'B').is_err());
        assert!(DNA.encode_str("ACGN").is_err());
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(Alphabet::by_name("dna").unwrap(), DNA);
        assert_eq!(Alphabet::by_name("protein").unwrap(), PROTEIN);
        assert!(Alphabet::by_name("rna").is_err());
    }
}
