//! Microarchitecture configuration (Table 1) and optimization toggles.

/// The four ApHMM optimizations (each individually disable-able, which is
/// how the Table 3 ablation is produced).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptToggles {
    /// LUTs holding common transition×emission products (§4.3).
    pub luts: bool,
    /// Broadcasting + partial compute of Backward values (§4.3).
    pub broadcast_partial: bool,
    /// Transition-numerator memoization in the UT scratchpad (§4.3).
    pub memoization: bool,
    /// Histogram filter instead of software sorting (§4.2).
    pub histogram_filter: bool,
}

impl OptToggles {
    /// All optimizations enabled (the evaluated design).
    pub fn all() -> Self {
        OptToggles { luts: true, broadcast_partial: true, memoization: true, histogram_filter: true }
    }

    /// All optimizations disabled (the naive hardware datapath).
    pub fn none() -> Self {
        OptToggles {
            luts: false,
            broadcast_partial: false,
            memoization: false,
            histogram_filter: false,
        }
    }
}

/// ApHMM core configuration (defaults = Table 1).
#[derive(Clone, Copy, Debug)]
pub struct AccelConfig {
    /// Processing engines per core (Table 1: 64).
    pub n_pes: usize,
    /// Multiply-accumulate lanes per PE (Table 1: 4 multipliers + 4 adders).
    pub lanes_per_pe: usize,
    /// Memory ports (Table 1: 8).
    pub mem_ports: usize,
    /// Bandwidth per port in bytes/cycle (Table 1: 16).
    pub port_bytes_per_cycle: usize,
    /// L1 cache size in KiB (Table 1: 128).
    pub l1_kb: usize,
    /// Update Transition units (Table 1: 64, scales with PEs).
    pub n_uts: usize,
    /// Update Emission units (Table 1: 4).
    pub n_ues: usize,
    /// States processed per UE per cycle.
    pub ue_throughput: usize,
    /// Clock frequency in GHz (§5.1: 1 GHz).
    pub freq_ghz: f64,
    /// Number of ApHMM cores (§4.4: 4).
    pub n_cores: usize,
    /// LUT entries per PE (§4.3: 36 = 4 emissions × 9 transitions).
    pub lut_entries: usize,
    /// Histogram filter size (Fig. 3 operating point: 500).
    pub filter_size: usize,
    /// Histogram filter bins (§4.2: 16).
    pub filter_bins: usize,
    /// UT memoization scratchpad in KiB (§4.3: 8).
    pub scratchpad_kb: usize,
    /// Optimization toggles.
    pub opt: OptToggles,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            n_pes: 64,
            lanes_per_pe: 4,
            mem_ports: 8,
            port_bytes_per_cycle: 16,
            l1_kb: 128,
            n_uts: 64,
            n_ues: 4,
            ue_throughput: 4,
            freq_ghz: 1.0,
            n_cores: 4,
            lut_entries: 36,
            filter_size: 500,
            filter_bins: 16,
            scratchpad_kb: 8,
            opt: OptToggles::all(),
        }
    }
}

impl AccelConfig {
    /// Peak MACs/cycle of the PE array.
    pub fn mac_per_cycle(&self) -> f64 {
        (self.n_pes * self.lanes_per_pe) as f64
    }

    /// Aggregate memory bandwidth in bytes/cycle.
    pub fn mem_bytes_per_cycle(&self) -> f64 {
        (self.mem_ports * self.port_bytes_per_cycle) as f64
    }

    /// Convert core cycles to seconds at the configured clock.
    pub fn cycles_to_seconds(&self, cycles: f64) -> f64 {
        cycles / (self.freq_ghz * 1e9)
    }

    /// LUT hit rate for alphabet size `sigma` and mean out-degree `d`:
    /// the LUT holds `lut_entries` products; a state needs `sigma × d`
    /// distinct products (§4.3: 4 × 7 = 28 ≤ 36 for DNA ⇒ full hit; the
    /// 20-letter protein alphabet overflows the LUT ⇒ partial).
    pub fn lut_hit_rate(&self, sigma: usize, degree: f64) -> f64 {
        if !self.opt.luts {
            return 0.0;
        }
        let needed = sigma as f64 * degree;
        (self.lut_entries as f64 / needed).min(1.0)
    }

    /// Scale the per-PE resources (UTs track PEs as in Table 1).
    pub fn with_pes(mut self, n_pes: usize) -> Self {
        self.n_pes = n_pes;
        self.n_uts = n_pes;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let c = AccelConfig::default();
        assert_eq!(c.n_pes, 64);
        assert_eq!(c.mac_per_cycle() as usize, 256);
        assert_eq!(c.mem_bytes_per_cycle() as usize, 128);
        assert_eq!(c.l1_kb, 128);
        assert_eq!(c.n_cores, 4);
    }

    #[test]
    fn lut_hit_rates_match_paper_argument() {
        let c = AccelConfig::default();
        // DNA: 4 × 7 = 28 products fit in 36 entries.
        assert_eq!(c.lut_hit_rate(4, 7.0), 1.0);
        // Protein: 20 × 7 = 140 products overflow.
        let r = c.lut_hit_rate(20, 7.0);
        assert!(r < 0.3 && r > 0.2, "r={r}");
        // Disabled LUTs never hit.
        let mut c2 = c;
        c2.opt.luts = false;
        assert_eq!(c2.lut_hit_rate(4, 7.0), 0.0);
    }

    #[test]
    fn with_pes_scales_uts() {
        let c = AccelConfig::default().with_pes(128);
        assert_eq!(c.n_uts, 128);
    }

    #[test]
    fn cycle_time_conversion() {
        let c = AccelConfig::default();
        assert!((c.cycles_to_seconds(1e9) - 1.0).abs() < 1e-12);
    }
}
