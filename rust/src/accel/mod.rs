//! The ApHMM accelerator model.
//!
//! The paper's evaluation numbers come from a synthesized 28 nm core plus
//! an analytical scale-up model (§5.1: "We develop an analytical model to
//! extract performance and area numbers for a scale-up configuration").
//! This module re-derives that analytical model from the microarchitecture
//! description (§4.3–4.4, Table 1, Table 2):
//!
//! * [`config`] — Table 1 microarchitecture configuration + the four
//!   optimization toggles (LUTs, broadcast/partial-compute, memoization,
//!   histogram filter);
//! * [`workload`] — workload descriptors extracted from *real* runs of
//!   the Rust Baum-Welch engine (active-state and edge counts), so the
//!   model is driven by measured workloads, not synthetic guesses;
//! * [`perf`] — the cycle model (compute vs memory-port roofline, 5 %
//!   arbitration, L1-capacity chunk pressure of Fig. 8c);
//! * [`energy`] — per-op/per-byte energy + static power (Fig. 10b);
//! * [`area`] — the Table 2 area/power breakdown, scaled by unit counts;
//! * [`baseline`] — CPU (measured), GPU and FPGA (paper-calibrated)
//!   comparison points;
//! * [`multicore`] — the Fig. 9 end-to-end multi-core scaling model.
//!
//! Calibration philosophy (DESIGN.md): constants with a physical source
//! are cited inline; constants the paper leaves unspecified are tuned so
//! the single design point of Table 1 balances compute and memory at 64
//! PEs (the knee of Fig. 8a) — the paper's own design argument.

mod area;
mod baseline;
mod config;
mod energy;
mod multicore;
mod perf;
mod workload;

pub use area::{area_power, AreaPower};
pub use baseline::{Baselines, CpuMeasurement};
pub use config::{AccelConfig, OptToggles};
pub use energy::{energy, EnergyBreakdown, EnergyConstants};
pub use multicore::{best_core_count, multicore_runtime, AppSplit, MulticoreResult};
pub use perf::{cycles, CycleBreakdown};
pub use workload::{StepKind, Workload};
