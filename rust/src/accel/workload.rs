//! Workload descriptors driving the cycle/energy models.
//!
//! A workload summarizes what the Baum-Welch algorithm actually had to do
//! for a batch of sequences on a given pHMM: how many timesteps ran, how
//! many states were active per timestep (post-filter), their mean
//! in/out-degree, and which steps of the algorithm executed.  Descriptors
//! are extracted from real engine runs ([`Workload::from_train_result`],
//! [`Workload::from_forward`]) or synthesized for design-space sweeps
//! ([`Workload::synthetic`]).

use crate::baumwelch::{ForwardResult, ScoreResult, TrainResult};
use crate::phmm::Phmm;

/// Which Baum-Welch steps a workload executes (§4.1: Backward and
/// Parameter Updates can be disabled per application).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// Forward only (pattern matching, some scoring paths).
    Forward,
    /// Forward + Backward (inference scoring: hmmsearch, hmmalign).
    ForwardBackward,
    /// Full training: Forward + Backward + Parameter Updates (Apollo).
    Training,
}

/// A measured or synthesized Baum-Welch workload.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Total timesteps executed (Σ over sequences of their lengths).
    pub total_steps: u64,
    /// Mean active states per timestep (post-filter).
    pub avg_active_states: f64,
    /// Mean transitions per active state.
    pub avg_degree: f64,
    /// Alphabet size Σ.
    pub sigma: usize,
    /// Total states in the pHMM graph (for maximization cost).
    pub n_states: u64,
    /// Chunk length the graph was built for (Fig. 8c pressure model).
    pub chunk_len: usize,
    /// Steps executed.
    pub steps: StepKind,
    /// Number of observation sequences.
    pub n_sequences: u64,
    /// EM iterations (training only).
    pub n_iterations: u64,
}

impl Workload {
    /// Extract from a training run (measured counters).
    pub fn from_train_result(phmm: &Phmm, res: &TrainResult, n_sequences: u64) -> Workload {
        let total_steps = res.timesteps.max(1);
        let avg_active_states = res.states_processed as f64 / total_steps as f64;
        let avg_degree = if res.states_processed > 0 {
            res.edges_processed as f64 / res.states_processed as f64
        } else {
            phmm.mean_out_degree()
        };
        Workload {
            total_steps,
            avg_active_states,
            avg_degree,
            sigma: phmm.sigma(),
            n_states: phmm.n_states() as u64,
            chunk_len: phmm.position.last().map(|&p| p as usize + 1).unwrap_or(0),
            steps: StepKind::Training,
            n_sequences,
            n_iterations: res.iters.max(1) as u64,
        }
    }

    /// Extract from a single forward pass (scoring workloads).
    pub fn from_forward(phmm: &Phmm, res: &ForwardResult, steps: StepKind) -> Workload {
        let t = res.rows.len() as u64;
        Workload {
            total_steps: t,
            avg_active_states: res.states_processed as f64 / t.max(1) as f64,
            avg_degree: if res.states_processed > 0 {
                res.edges_processed as f64 / res.states_processed as f64
            } else {
                phmm.mean_out_degree()
            },
            sigma: phmm.sigma(),
            n_states: phmm.n_states() as u64,
            chunk_len: phmm.position.last().map(|&p| p as usize + 1).unwrap_or(0),
            steps,
            n_sequences: 1,
            n_iterations: 1,
        }
    }

    /// Extract from a score-only pass.  [`ScoreResult`] is the uniform
    /// output of every [`crate::baumwelch::ExpectationEngine`]'s
    /// forward path, so inference workloads (protein search, MSA
    /// pre-screening) feed the accelerator model identically whichever
    /// backend produced them; `timesteps` is the query length (the
    /// score path does not materialize rows to count).
    pub fn from_score(
        phmm: &Phmm,
        res: &ScoreResult,
        timesteps: u64,
        steps: StepKind,
    ) -> Workload {
        let t = timesteps.max(1);
        Workload {
            total_steps: t,
            avg_active_states: res.states_processed as f64 / t as f64,
            avg_degree: if res.states_processed > 0 {
                res.edges_processed as f64 / res.states_processed as f64
            } else {
                phmm.mean_out_degree()
            },
            sigma: phmm.sigma(),
            n_states: phmm.n_states() as u64,
            chunk_len: phmm.position.last().map(|&p| p as usize + 1).unwrap_or(0),
            steps,
            n_sequences: 1,
            n_iterations: 1,
        }
    }

    /// Synthesize a workload for design-space sweeps (Fig. 8).
    pub fn synthetic(
        total_steps: u64,
        avg_active_states: f64,
        avg_degree: f64,
        sigma: usize,
        chunk_len: usize,
        steps: StepKind,
    ) -> Workload {
        Workload {
            total_steps,
            avg_active_states,
            avg_degree,
            sigma,
            n_states: (chunk_len * 4) as u64,
            chunk_len,
            steps,
            n_sequences: 1,
            n_iterations: 1,
        }
    }

    /// The paper's canonical error-correction operating point: chunked
    /// DNA training at filter size 500 with the EC design's ~7 degree.
    pub fn ec_canonical() -> Workload {
        Workload::synthetic(1000, 500.0, 7.0, 4, 650, StepKind::Training)
    }

    /// Protein-search operating point: ~94-residue profiles, Σ=20,
    /// Forward+Backward only.
    pub fn protein_canonical() -> Workload {
        let mut w = Workload::synthetic(94, 280.0, 3.0, 20, 94, StepKind::ForwardBackward);
        w.n_states = 282;
        w
    }

    /// Total edge traversals per Baum-Welch pass.
    pub fn total_edges(&self) -> f64 {
        self.total_steps as f64 * self.avg_active_states * self.avg_degree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::{forward_sparse, train, FilterConfig, ForwardOptions, TrainConfig};
    use crate::phmm::EcDesignParams;
    use crate::seq::Sequence;
    use crate::sim::XorShift;
    use crate::testutil;

    #[test]
    fn from_forward_extracts_counts() {
        let mut rng = XorShift::new(1);
        let reference = Sequence::from_symbols("r", testutil::random_seq(&mut rng, 100, 4));
        let g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 50, 4));
        let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
        let wl = Workload::from_forward(&g, &fwd, StepKind::ForwardBackward);
        assert_eq!(wl.total_steps, 50);
        assert!(wl.avg_active_states > 1.0);
        assert!(wl.avg_degree > 1.0 && wl.avg_degree < 12.0);
        assert_eq!(wl.sigma, 4);
    }

    #[test]
    fn from_train_result_with_filter() {
        let mut rng = XorShift::new(2);
        let reference = Sequence::from_symbols("r", testutil::random_seq(&mut rng, 200, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let reads: Vec<Sequence> = (0..3)
            .map(|_| Sequence::from_symbols("o", testutil::random_seq(&mut rng, 100, 4)))
            .collect();
        let res = train(
            &mut g,
            &reads,
            &TrainConfig {
                max_iters: 1,
                tol: 0.0,
                filter: FilterConfig::Sort { size: 64 },
                ..Default::default()
            },
        )
        .unwrap();
        let wl = Workload::from_train_result(&g, &res, 3);
        assert!(wl.avg_active_states <= 64.0 + 1e-9);
        assert_eq!(wl.steps, StepKind::Training);
        assert!(wl.total_steps >= 300);
    }

    #[test]
    fn from_score_matches_from_forward_counters() {
        // The score fast path and the row-materializing forward report
        // the same workload counters, so the extracted descriptors must
        // agree whichever inference path produced them.
        use crate::baumwelch::score_sparse_with;
        use crate::baumwelch::{ForwardScratch, FusedCoeffs};
        let mut rng = XorShift::new(3);
        let reference = Sequence::from_symbols("r", testutil::random_seq(&mut rng, 80, 4));
        let g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 40, 4));
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        let score =
            score_sparse_with(&g, &coeffs, &obs, &ForwardOptions::default(), &mut scratch)
                .unwrap();
        let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
        let ws = Workload::from_score(&g, &score, obs.len() as u64, StepKind::Forward);
        let wf = Workload::from_forward(&g, &fwd, StepKind::Forward);
        assert_eq!(ws.total_steps, wf.total_steps);
        assert!((ws.avg_active_states - wf.avg_active_states).abs() < 1e-9);
        assert!((ws.avg_degree - wf.avg_degree).abs() < 1e-9);
        assert_eq!(ws.steps, StepKind::Forward);
    }

    #[test]
    fn total_edges_consistent() {
        let wl = Workload::ec_canonical();
        let expect = 1000.0 * 500.0 * 7.0;
        assert!((wl.total_edges() - expect).abs() < 1e-6);
    }
}
