//! Baseline platforms for the Fig. 10/11 comparisons.
//!
//! * **CPU** — genuinely measured: the caller times the Rust sparse
//!   engine (our reimplementation of the Apollo/HMMER compute) and wraps
//!   the measurement in [`CpuMeasurement`].  Energy = time × package
//!   power.
//! * **GPU / FPGA** — no such hardware exists here, so these are
//!   calibrated from the paper's *reported relative throughputs*
//!   (DESIGN.md substitution table): ApHMM is 1.83–5.34× faster than the
//!   GPU implementations (GPUs win on Forward-only) and 27.97× faster
//!   than the FPGA D&C accelerator.  They reproduce the *shape* of the
//!   comparison by construction and are clearly labelled as modeled.

use super::config::AccelConfig;
use super::energy::{energy, EnergyConstants};
use super::perf::cycles;
use super::workload::{StepKind, Workload};

/// Active package power of the measured CPU baseline (W).  A single
/// active core of a server-class part (the paper uses an AMD EPYC 7742);
/// 80 W keeps the paper's energy ratios consistent (see DESIGN.md).
pub const CPU_ACTIVE_POWER_W: f64 = 80.0;

/// Active board power of the modeled GPU baseline (W) — A100 class.
pub const GPU_ACTIVE_POWER_W: f64 = 250.0;

/// A wall-clock measurement of the CPU engine.
#[derive(Clone, Copy, Debug)]
pub struct CpuMeasurement {
    /// Measured seconds for the workload.
    pub seconds: f64,
    /// Share of that time spent in sort-based filtering (Obs. 4: ≈8.5 %
    /// during training when filtering is enabled).
    pub filter_fraction: f64,
}

impl CpuMeasurement {
    /// Energy of the measurement (J).
    pub fn joules(&self) -> f64 {
        self.seconds * CPU_ACTIVE_POWER_W
    }
}

/// All comparison points for one workload.
#[derive(Clone, Copy, Debug)]
pub struct Baselines {
    /// Measured CPU single-thread seconds.
    pub cpu_s: f64,
    /// Modeled GPU seconds (paper-calibrated).
    pub gpu_s: f64,
    /// Modeled FPGA D&C seconds (paper-calibrated).
    pub fpga_s: f64,
    /// Modeled ApHMM seconds (single core).
    pub aphmm_s: f64,
    /// CPU energy (J).
    pub cpu_j: f64,
    /// GPU energy (J).
    pub gpu_j: f64,
    /// ApHMM energy (J).
    pub aphmm_j: f64,
}

impl Baselines {
    /// Build the comparison set from a real CPU measurement.
    ///
    /// GPU calibration: the paper reports ApHMM 1.83–5.34× faster than
    /// GPU overall but GPUs *faster* than ApHMM on the Forward-only
    /// kernel (§5.3, observation five) — we encode a 3.5× average for
    /// full Baum-Welch and 0.8× for Forward-heavy scoring workloads.
    pub fn from_cpu_measurement(cfg: &AccelConfig, wl: &Workload, cpu: &CpuMeasurement) -> Baselines {
        let aphmm_s = cycles(cfg, wl).seconds(cfg);
        let gpu_factor = match wl.steps {
            StepKind::Forward => 0.8,
            StepKind::ForwardBackward => 2.5,
            StepKind::Training => 3.5,
        };
        let gpu_s = aphmm_s * gpu_factor;
        let fpga_s = aphmm_s * 27.97;
        let aphmm_j = energy(cfg, wl, &EnergyConstants::default()).total();
        Baselines {
            cpu_s: cpu.seconds,
            gpu_s,
            fpga_s,
            aphmm_s,
            cpu_j: cpu.joules(),
            gpu_j: gpu_s * GPU_ACTIVE_POWER_W,
            aphmm_j,
        }
    }

    /// Speedup of ApHMM over each platform.
    pub fn speedups(&self) -> (f64, f64, f64) {
        (self.cpu_s / self.aphmm_s, self.gpu_s / self.aphmm_s, self.fpga_s / self.aphmm_s)
    }

    /// Energy reduction of ApHMM vs CPU and GPU.
    pub fn energy_reductions(&self) -> (f64, f64) {
        (self.cpu_j / self.aphmm_j, self.gpu_j / self.aphmm_j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_paper() {
        // CPU slowest, GPU in between, ApHMM fastest, FPGA slower than
        // GPU (the paper's 27.97x vs 1.83-5.34x).
        let cfg = AccelConfig::default();
        let wl = Workload::ec_canonical();
        let aphmm_s = cycles(&cfg, &wl).seconds(&cfg);
        let cpu = CpuMeasurement { seconds: aphmm_s * 50.0, filter_fraction: 0.085 };
        let b = Baselines::from_cpu_measurement(&cfg, &wl, &cpu);
        assert!(b.cpu_s > b.gpu_s);
        assert!(b.gpu_s > b.aphmm_s);
        assert!(b.fpga_s > b.gpu_s);
        let (s_cpu, s_gpu, s_fpga) = b.speedups();
        assert!(s_cpu > s_gpu && s_gpu > 1.0);
        assert!((s_fpga - 27.97).abs() < 1e-6);
    }

    #[test]
    fn gpu_wins_forward_only() {
        // §5.3: "GPU implementations are a better candidate for
        // applications that execute only the Forward calculations".
        let cfg = AccelConfig::default();
        let mut wl = Workload::ec_canonical();
        wl.steps = StepKind::Forward;
        let aphmm_s = cycles(&cfg, &wl).seconds(&cfg);
        let cpu = CpuMeasurement { seconds: 1.0, filter_fraction: 0.0 };
        let b = Baselines::from_cpu_measurement(&cfg, &wl, &cpu);
        assert!(b.gpu_s < aphmm_s * 1.01);
    }

    #[test]
    fn energy_reductions_positive() {
        let cfg = AccelConfig::default();
        let wl = Workload::ec_canonical();
        let aphmm_s = cycles(&cfg, &wl).seconds(&cfg);
        let cpu = CpuMeasurement { seconds: aphmm_s * 100.0, filter_fraction: 0.085 };
        let b = Baselines::from_cpu_measurement(&cfg, &wl, &cpu);
        let (e_cpu, e_gpu) = b.energy_reductions();
        assert!(e_cpu > 10.0, "cpu energy reduction {e_cpu}");
        assert!(e_gpu > 1.0, "gpu energy reduction {e_gpu}");
    }
}
