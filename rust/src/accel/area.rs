//! Area and power model (Table 2).
//!
//! Per-module densities are taken directly from the paper's synthesis
//! results (Synopsys DC, 28 nm, 1 GHz) and scaled linearly with unit
//! counts, so non-default configurations (Fig. 8 sweeps) get consistent
//! area/power estimates.

use super::config::AccelConfig;

/// Table 2 synthesis constants (one ApHMM core, Table 1 configuration).
mod table2 {
    /// 64 PEs: 1.333 mm².
    pub const PE_AREA_MM2: f64 = 1.333 / 64.0;
    /// 64 PEs: 304.2 mW (includes their L1 access activity).
    pub const PE_POWER_MW: f64 = 304.2 / 64.0;
    /// 64 UTs: 5.097 mm² (mux + division pipeline + local memory).
    pub const UT_AREA_MM2: f64 = 5.097 / 64.0;
    /// 64 UTs: 0.8 mW.
    pub const UT_POWER_MW: f64 = 0.8 / 64.0;
    /// 4 UEs: 0.094 mm².
    pub const UE_AREA_MM2: f64 = 0.094 / 4.0;
    /// 4 UEs: 70.4 mW.
    pub const UE_POWER_MW: f64 = 70.4 / 4.0;
    /// 128 KB L1: 0.632 mm².
    pub const L1_AREA_MM2_PER_KB: f64 = 0.632 / 128.0;
    /// 128 KB L1: 100 mW.
    pub const L1_POWER_MW_PER_KB: f64 = 100.0 / 128.0;
    /// Control Block power (Table 2 attributes ~86 % of power to Control
    /// Block + PEs; the control share is the remainder of the 509.8 mW
    /// core total): 509.8 - 304.2 - 0.8 - 70.4 - 100 = 34.4 mW.
    pub const CONTROL_POWER_MW: f64 = 34.4;
    /// Control Block area: Table 2 total 6.536 - listed modules.
    pub const CONTROL_AREA_MM2: f64 = 6.536 - 1.333 - 5.097 - 0.094 - 0.632 * 0.0;
}

/// Area/power estimate of one configuration.
#[derive(Clone, Copy, Debug)]
pub struct AreaPower {
    /// PE array area (mm²).
    pub pe_area_mm2: f64,
    /// UT array area (mm²).
    pub ut_area_mm2: f64,
    /// UE array area (mm²).
    pub ue_area_mm2: f64,
    /// L1 memory area (mm²).
    pub l1_area_mm2: f64,
    /// Control block area (mm²).
    pub control_area_mm2: f64,
    /// PE array power (mW).
    pub pe_power_mw: f64,
    /// UT array power (mW).
    pub ut_power_mw: f64,
    /// UE array power (mW).
    pub ue_power_mw: f64,
    /// L1 power (mW).
    pub l1_power_mw: f64,
    /// Control block power (mW).
    pub control_power_mw: f64,
}

impl AreaPower {
    /// Total logic area of one core (mm², excluding L1 as in Table 2's
    /// "Overall" row).
    pub fn core_area_mm2(&self) -> f64 {
        self.pe_area_mm2 + self.ut_area_mm2 + self.ue_area_mm2 + self.control_area_mm2
    }

    /// Total core power (mW) including L1.
    pub fn core_power_mw(&self) -> f64 {
        self.pe_power_mw
            + self.ut_power_mw
            + self.ue_power_mw
            + self.l1_power_mw
            + self.control_power_mw
    }

    /// Full-chip area for `n_cores` (mm², L1 included per core).
    pub fn chip_area_mm2(&self, n_cores: usize) -> f64 {
        (self.core_area_mm2() + self.l1_area_mm2) * n_cores as f64
    }

    /// Full-chip power for `n_cores` (W).
    pub fn chip_power_w(&self, n_cores: usize) -> f64 {
        self.core_power_mw() * n_cores as f64 / 1000.0
    }
}

/// Scale Table 2 to an arbitrary configuration.
pub fn area_power(cfg: &AccelConfig) -> AreaPower {
    AreaPower {
        pe_area_mm2: table2::PE_AREA_MM2 * cfg.n_pes as f64,
        ut_area_mm2: table2::UT_AREA_MM2 * cfg.n_uts as f64,
        ue_area_mm2: table2::UE_AREA_MM2 * cfg.n_ues as f64,
        l1_area_mm2: table2::L1_AREA_MM2_PER_KB * cfg.l1_kb as f64,
        control_area_mm2: table2::CONTROL_AREA_MM2,
        pe_power_mw: table2::PE_POWER_MW * cfg.n_pes as f64,
        ut_power_mw: table2::UT_POWER_MW * cfg.n_uts as f64,
        ue_power_mw: table2::UE_POWER_MW * cfg.n_ues as f64,
        l1_power_mw: table2::L1_POWER_MW_PER_KB * cfg.l1_kb as f64,
        control_power_mw: table2::CONTROL_POWER_MW,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_reproduces_table2_totals() {
        let ap = area_power(&AccelConfig::default());
        // Table 2: overall 6.536 mm², 509.8 mW; 128KB L1 0.632 mm², 100 mW.
        assert!((ap.core_area_mm2() - 6.536).abs() < 0.02, "area {}", ap.core_area_mm2());
        assert!((ap.core_power_mw() - 509.8).abs() < 1.0, "power {}", ap.core_power_mw());
        assert!((ap.l1_area_mm2 - 0.632).abs() < 1e-9);
    }

    #[test]
    fn ut_dominates_area_pe_dominates_power() {
        // Table 2's headline observations (§5.2): UTs are 77.98 % of
        // area; Control+PE dominate power.
        let ap = area_power(&AccelConfig::default());
        assert!(ap.ut_area_mm2 / ap.core_area_mm2() > 0.7);
        assert!((ap.pe_power_mw + ap.control_power_mw) / ap.core_power_mw() > 0.6);
    }

    #[test]
    fn scaling_is_linear_in_units() {
        let small = area_power(&AccelConfig::default().with_pes(32));
        let big = area_power(&AccelConfig::default().with_pes(128));
        assert!((big.pe_area_mm2 / small.pe_area_mm2 - 4.0).abs() < 1e-9);
        assert!((big.ut_power_mw / small.ut_power_mw - 4.0).abs() < 1e-9);
    }

    #[test]
    fn four_core_chip() {
        let ap = area_power(&AccelConfig::default());
        let area = ap.chip_area_mm2(4);
        let power = ap.chip_power_w(4);
        assert!((area - 4.0 * (6.536 + 0.632)).abs() < 0.1);
        assert!((power - 4.0 * 0.5098).abs() < 0.01);
    }
}
