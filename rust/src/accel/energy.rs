//! Energy model (Fig. 10b).
//!
//! `E = dynamic (ops × e_op + bytes × e_byte) + static (power × time)`.
//! Op energies follow Horowitz's ISSCC'14 survey scaled from 45 nm to
//! 28 nm (×0.7): f32 multiply 3.7 pJ → 2.6 pJ, f32 add 0.9 pJ → 0.63 pJ.
//! Memory energies use the conventional SRAM/DRAM ladder (L1 ≈0.6 pJ/B,
//! L2 ≈1.2 pJ/B, DRAM ≈20 pJ/B).  Static power comes from the Table 2
//! synthesis numbers via [`super::area_power`].

use super::area::area_power;
use super::config::AccelConfig;
use super::perf::{cycles, CycleBreakdown};
use super::workload::{StepKind, Workload};

/// Energy constants (pJ).
#[derive(Clone, Copy, Debug)]
pub struct EnergyConstants {
    /// f32 multiply (pJ).
    pub e_mul: f64,
    /// f32 add (pJ).
    pub e_add: f64,
    /// L1 SRAM access (pJ/byte).
    pub e_l1_byte: f64,
    /// L2 SRAM access (pJ/byte).
    pub e_l2_byte: f64,
    /// DRAM access (pJ/byte).
    pub e_dram_byte: f64,
}

impl Default for EnergyConstants {
    fn default() -> Self {
        EnergyConstants { e_mul: 2.6, e_add: 0.63, e_l1_byte: 0.6, e_l2_byte: 1.2, e_dram_byte: 20.0 }
    }
}

/// Energy breakdown of one Baum-Welch execution (joules).
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    /// MAC energy (J).
    pub compute_j: f64,
    /// On-chip memory traffic energy (J).
    pub sram_j: f64,
    /// Off-chip traffic energy (J).
    pub dram_j: f64,
    /// Static/leakage energy (J).
    pub static_j: f64,
}

impl EnergyBreakdown {
    /// Total energy (J).
    pub fn total(&self) -> f64 {
        self.compute_j + self.sram_j + self.dram_j + self.static_j
    }
}

/// Estimate the energy of executing `wl` on one ApHMM core.
pub fn energy(cfg: &AccelConfig, wl: &Workload, k: &EnergyConstants) -> EnergyBreakdown {
    let bd: CycleBreakdown = cycles(cfg, wl);
    let seconds = bd.seconds(cfg);

    // Operation counts mirror the cycle model's compute terms.
    let t = wl.total_steps as f64;
    let edges = wl.avg_active_states * wl.avg_degree * t;
    let n_passes = match wl.steps {
        StepKind::Forward => 1.0,
        StepKind::ForwardBackward => 2.0,
        StepKind::Training => 3.0, // fwd + bwd + UT numerators
    };
    let macs = edges * n_passes;
    let compute_j = macs * (k.e_mul + k.e_add) * 1e-12;

    // Traffic: per-state and per-edge bytes as in the cycle model; split
    // on-chip vs off-chip by the chunk spill behaviour (approximated:
    // forward rows stream to L2/DRAM once per pass — §5.3's observation
    // that Forward dominates ApHMM time via L2/DRAM traffic).
    let lut_hit = cfg.lut_hit_rate(wl.sigma, wl.avg_degree);
    let per_edge = lut_hit * 0.5 + (1.0 - lut_hit) * 8.0;
    let sram_bytes = t * wl.avg_active_states * 20.0 + edges * per_edge;
    let dram_bytes = t * wl.avg_active_states * 4.0 * if wl.steps == StepKind::Training { 2.0 } else { 1.0 };
    let sram_j = sram_bytes * k.e_l1_byte * 1e-12 + sram_bytes * 0.25 * k.e_l2_byte * 1e-12;
    let dram_j = dram_bytes * k.e_dram_byte * 1e-12;

    let static_j = area_power(cfg).core_power_mw() / 1000.0 * seconds;
    EnergyBreakdown { compute_j, sram_j, dram_j, static_j }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_positive_and_dominated_by_dynamic_for_long_runs() {
        let e = energy(&AccelConfig::default(), &Workload::ec_canonical(), &Default::default());
        assert!(e.total() > 0.0);
        assert!(e.compute_j > 0.0 && e.sram_j > 0.0 && e.dram_j > 0.0 && e.static_j > 0.0);
    }

    #[test]
    fn training_costs_more_than_scoring() {
        let k = EnergyConstants::default();
        let mut wl = Workload::ec_canonical();
        let train_e = energy(&AccelConfig::default(), &wl, &k).total();
        wl.steps = StepKind::ForwardBackward;
        let score_e = energy(&AccelConfig::default(), &wl, &k).total();
        assert!(train_e > score_e);
    }

    #[test]
    fn protein_alphabet_increases_energy_per_step() {
        // Larger Σ overflows the LUTs -> more operand traffic per edge.
        let k = EnergyConstants::default();
        let dna = Workload::ec_canonical();
        let mut pro = dna;
        pro.sigma = 20;
        let e_dna = energy(&AccelConfig::default(), &dna, &k).total();
        let e_pro = energy(&AccelConfig::default(), &pro, &k).total();
        assert!(e_pro > e_dna);
    }

    #[test]
    fn energy_scales_with_workload() {
        let k = EnergyConstants::default();
        let mut small = Workload::ec_canonical();
        small.total_steps = 100;
        let mut big = small;
        big.total_steps = 10_000;
        let e_s = energy(&AccelConfig::default(), &small, &k).total();
        let e_b = energy(&AccelConfig::default(), &big, &k).total();
        assert!(e_b > 50.0 * e_s);
    }
}
