//! The ApHMM cycle model (Fig. 8, Fig. 10a, Table 3).
//!
//! Per timestep the model computes compute cycles (MACs over the PE/UT/UE
//! arrays) and memory cycles (operand traffic over the 8×16 B/cycle port
//! complex), takes the max (the §4.4 roofline argument) and adds the 5 %
//! arbitration surcharge of §5.1.  The four optimizations act exactly as
//! the paper describes them:
//!
//! * **LUTs** remove the per-edge transition+emission operand fetch for
//!   products resident in the 36-entry LUT ("bandwidth reduction of up to
//!   66 % per PE"): per-edge traffic drops from 8 B (α + e operands) to
//!   control-metadata only.
//! * **Broadcast + partial compute** avoid materializing the Backward
//!   matrix: per-state Backward traffic drops 4× ("32 bits/cycle instead
//!   of 128 bits/cycle").
//! * **Memoization** keeps transition-update numerators in the 8 KB UT
//!   scratchpad: UT traffic halves ("reducing the bandwidth requirement
//!   by 2× per UT") and the re-fetch of F values for the numerator is
//!   avoided.
//! * **Histogram filter** replaces the software sort: selection overlaps
//!   the PE writeback (≈free) at the cost of bin-granular state
//!   overshoot (measured ≈10 % on our workloads).
//!
//! Constants the paper does not pin down are calibrated so the Table 1
//! design point balances compute and memory at 64 PEs — the knee of
//! Fig. 8a, which is the paper's own design-space argument.

use super::config::AccelConfig;
use super::workload::{StepKind, Workload};

/// Traffic constants (bytes), documented against the paper's claims.
///
/// The calibration anchor: with every optimization on, the Table 1
/// design point must sit at the compute/memory knee (Fig. 8a).  At 64
/// PEs × 4 lanes the array retires 256 MACs/cycle against 128 B/cycle of
/// port bandwidth, i.e. 0.5 B per MAC of headroom — so the optimized
/// per-state traffic must be ~4 B (one f32 result write), with operand
/// distribution happening on the broadcast bus and in the LUTs rather
/// than through the ports.  That is precisely the paper's argument for
/// "decoupling hardware scaling from bandwidth requirements".
mod bytes {
    /// Result write per active state per timestep (F̂_t or B̂_t, one f32).
    pub const STATE_RESULT: f64 = 4.0;
    /// Operand fetch per edge when the source value is NOT broadcast:
    /// each of the d incoming contributions re-reads its F/B operand.
    pub const EDGE_OPERAND_NO_BCAST: f64 = 4.0;
    /// Transition-probability fetch per edge on LUT miss (the α·e
    /// product must be formed in the TE MUL unit from an α fetched
    /// through the ports; the emission column is a single vector per
    /// timestep, amortized to ~0).
    pub const EDGE_LUT_MISS: f64 = 4.0;
    /// Extra per-state traffic when backward values are materialized
    /// instead of consumed in flight (stored B̂ row re-read by UT + UE).
    pub const BWD_MATERIALIZE_EXTRA: f64 = 8.0;
    /// UT numerator traffic per edge with memoization on (scratchpad).
    pub const UT_MEMO: f64 = 1.0;
    /// UT numerator traffic per edge with memoization off: numerators
    /// round-trip L1 (2× per the paper) and the F operand of the
    /// numerator is re-fetched.
    pub const UT_NO_MEMO: f64 = 6.0;
    /// Emission-update traffic per active state (numerator + denominator
    /// accumulate in L1, §4.3).
    pub const UE: f64 = 4.0;
}

/// Histogram-filter state overshoot (bin-granular admission, §4.2);
/// measured ≈1.1 on EC workloads with 16 bins at filter size 500.
const HISTOGRAM_OVERSHOOT: f64 = 1.10;

/// Port arbitration surcharge (§5.1: "an additional 5 % of cycles").
const ARBITRATION: f64 = 1.05;

/// L2/DRAM spill latency multiplier applied to the traffic that misses
/// L1 when the chunk working set exceeds capacity (Fig. 8c).
const SPILL_PENALTY: f64 = 4.0;

/// Cycle breakdown of one Baum-Welch execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct CycleBreakdown {
    /// Forward-calculation cycles.
    pub forward: f64,
    /// Backward-calculation cycles (0 when the step is disabled).
    pub backward: f64,
    /// Parameter-update cycles (transition + emission + maximization).
    pub update: f64,
    /// Memory-stall share of the total (diagnostic).
    pub mem_bound_fraction: f64,
}

impl CycleBreakdown {
    /// Total cycles.
    pub fn total(&self) -> f64 {
        self.forward + self.backward + self.update
    }

    /// Seconds at `cfg`'s clock.
    pub fn seconds(&self, cfg: &AccelConfig) -> f64 {
        cfg.cycles_to_seconds(self.total())
    }
}

/// L1-resident working set of the Baum-Welch parameters for a chunk
/// (Supplemental Fig. S1).  Forward rows are *not* included: they stream
/// to L2/DRAM by design (§5.3's third observation) and their traffic is
/// in the per-timestep byte counts instead.  What must stay resident is
/// the emission numerators/denominators and the transition parameters of
/// the sub-graph the chunk activates.
fn working_set_bytes(wl: &Workload) -> f64 {
    let n = wl.n_states as f64;
    // Emission numerators + denominator: N × (Σ + 1) × 4B.
    let emissions = n * (wl.sigma as f64 + 1.0) * 4.0;
    // Transition parameters: N × degree × 4B.
    let graph = n * wl.avg_degree * 4.0;
    // Two live state rows (F̂ current + B̂ broadcast row).
    let rows = 2.0 * wl.avg_active_states * 4.0;
    emissions + graph + rows
}

/// Fraction of traffic spilling past L1 for this chunk size.
fn spill_fraction(cfg: &AccelConfig, wl: &Workload) -> f64 {
    let l1 = (cfg.l1_kb * 1024) as f64;
    let ws = working_set_bytes(wl);
    if ws <= l1 {
        0.0
    } else {
        ((ws - l1) / ws).min(0.9)
    }
}

/// Cycles for one Baum-Welch execution of workload `wl` on one core.
pub fn cycles(cfg: &AccelConfig, wl: &Workload) -> CycleBreakdown {
    let macs = cfg.mac_per_cycle();
    let bw = cfg.mem_bytes_per_cycle();
    let spill = spill_fraction(cfg, wl);
    let mem_penalty = 1.0 + spill * (SPILL_PENALTY - 1.0);

    // Active states per timestep: histogram overshoot when enabled.
    // Without the hardware filter the accelerator still receives the
    // software-filtered workload (the filter then costs sort time on the
    // host — accounted in the CPU/overhead models, not here).
    let n_act = if cfg.opt.histogram_filter {
        wl.avg_active_states * HISTOGRAM_OVERSHOOT
    } else {
        wl.avg_active_states
    };
    let edges = n_act * wl.avg_degree;
    let lut_hit = cfg.lut_hit_rate(wl.sigma, wl.avg_degree);
    // Per-edge operand traffic: α·e products come from the LUT on a hit;
    // on a miss the α operand flows through the ports into the TE MUL.
    let edge_bytes = (1.0 - lut_hit) * bytes::EDGE_LUT_MISS;
    // Per-edge source-value traffic: free on the broadcast bus, a full
    // operand fetch per edge without it.
    let bcast_edge_bytes =
        if cfg.opt.broadcast_partial { 0.0 } else { bytes::EDGE_OPERAND_NO_BCAST };

    // ---- Forward (per timestep) ----
    let fwd_compute = edges / macs;
    let fwd_bytes = n_act * bytes::STATE_RESULT + edges * (edge_bytes + bcast_edge_bytes);
    let fwd_mem = fwd_bytes * mem_penalty / bw;
    let fwd_cycles = fwd_compute.max(fwd_mem) * ARBITRATION;

    // ---- Backward (per timestep) ----
    let run_backward = wl.steps != StepKind::Forward;
    let (bwd_cycles, bwd_mem, bwd_compute) = if run_backward {
        let compute = edges / macs;
        let per_state = bytes::STATE_RESULT
            + if cfg.opt.broadcast_partial { 0.0 } else { bytes::BWD_MATERIALIZE_EXTRA };
        let b = n_act * per_state + edges * (edge_bytes + bcast_edge_bytes);
        let mem = b * mem_penalty / bw;
        (compute.max(mem) * ARBITRATION, mem, compute)
    } else {
        (0.0, 0.0, 0.0)
    };

    // ---- Parameter updates (per timestep, training only) ----
    let run_update = wl.steps == StepKind::Training;
    let (upd_cycles, upd_mem, upd_compute) = if run_update {
        // UT: one MAC per edge across n_uts units.
        let ut_compute = edges / cfg.n_uts as f64;
        let ut_bytes = edges * if cfg.opt.memoization { bytes::UT_MEMO } else { bytes::UT_NO_MEMO };
        // UE: numerator+denominator accumulate per state.
        let ue_compute = n_act / (cfg.n_ues * cfg.ue_throughput) as f64;
        let ue_bytes = n_act * bytes::UE;
        let compute = ut_compute + ue_compute;
        let mem = (ut_bytes + ue_bytes) * mem_penalty / bw;
        (compute.max(mem) * ARBITRATION, mem, compute)
    } else {
        (0.0, 0.0, 0.0)
    };

    let t = wl.total_steps as f64;
    let mut bd = CycleBreakdown {
        forward: fwd_cycles * t,
        backward: bwd_cycles * t,
        update: upd_cycles * t,
        mem_bound_fraction: 0.0,
    };

    // Maximization (once per EM iteration): a division per transition
    // and per emission entry through the UT division pipelines.
    if run_update {
        let divs = wl.n_states as f64 * (wl.avg_degree + wl.sigma as f64);
        bd.update += wl.n_iterations as f64 * divs / cfg.n_uts as f64;
    }

    let mem_c = (fwd_mem + bwd_mem + upd_mem) * t;
    let comp_c = (fwd_compute + bwd_compute + upd_compute) * t;
    bd.mem_bound_fraction = if mem_c + comp_c > 0.0 { mem_c / (mem_c + comp_c) } else { 0.0 };
    bd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::OptToggles;

    fn dna_training() -> Workload {
        Workload::ec_canonical()
    }

    #[test]
    fn table1_design_point_is_roughly_balanced() {
        // The paper's design argument: at 64 PEs / 8 ports the core sits
        // at the compute-memory knee (Fig. 8a).
        let cfg = AccelConfig::default();
        let bd = cycles(&cfg, &dna_training());
        assert!(
            (0.25..0.75).contains(&bd.mem_bound_fraction),
            "mem fraction {} not near knee",
            bd.mem_bound_fraction
        );
    }

    #[test]
    fn scaling_knees_at_64_pes() {
        // Linear-ish gains up to 64 PEs, then diminishing (Fig. 8a).
        let wl = dna_training();
        let t = |pes: usize| cycles(&AccelConfig::default().with_pes(pes), &wl).total();
        let gain_8_to_64 = t(8) / t(64);
        let gain_64_to_512 = t(64) / t(512);
        assert!(gain_8_to_64 > 3.0, "gain 8->64 = {gain_8_to_64}");
        assert!(gain_64_to_512 < 2.0, "gain 64->512 = {gain_64_to_512}");
    }

    #[test]
    fn each_optimization_helps() {
        let wl = dna_training();
        let all = cycles(&AccelConfig::default(), &wl).total();
        for (name, toggle) in [
            ("luts", OptToggles { luts: false, ..OptToggles::all() }),
            ("broadcast", OptToggles { broadcast_partial: false, ..OptToggles::all() }),
            ("memo", OptToggles { memoization: false, ..OptToggles::all() }),
        ] {
            let mut cfg = AccelConfig::default();
            cfg.opt = toggle;
            let worse = cycles(&cfg, &wl).total();
            assert!(worse > all * 1.05, "{name}: {worse} vs {all}");
        }
    }

    #[test]
    fn ablation_factors_in_paper_ballpark() {
        // Table 3: LUTs 2.48x, broadcast+partial 3.39x, memoization
        // 1.69x.  Our analytically derived factors must land within
        // ±40 % of the paper's (the substrate differs).
        let wl = dna_training();
        let all = cycles(&AccelConfig::default(), &wl).total();
        let factor = |toggle: OptToggles| {
            let mut cfg = AccelConfig::default();
            cfg.opt = toggle;
            cycles(&cfg, &wl).total() / all
        };
        let lut = factor(OptToggles { luts: false, ..OptToggles::all() });
        let bcast = factor(OptToggles { broadcast_partial: false, ..OptToggles::all() });
        let memo = factor(OptToggles { memoization: false, ..OptToggles::all() });
        assert!((1.5..3.5).contains(&lut), "lut factor {lut}");
        assert!((1.9..4.8).contains(&bcast), "broadcast factor {bcast}");
        assert!((1.1..2.4).contains(&memo), "memo factor {memo}");
    }

    #[test]
    fn chunk_pressure_nonlinear_beyond_650(){
        // Fig. 8c: execution time grows linearly to ~650 bases, then
        // super-linearly (L1 spill).
        let cfg = AccelConfig::default();
        let t = |chunk: usize| {
            let wl = Workload::synthetic(
                chunk as u64,
                500.0,
                7.0,
                4,
                chunk,
                StepKind::Training,
            );
            cycles(&cfg, &wl).total()
        };
        let per_base_150 = t(150) / 150.0;
        let per_base_650 = t(650) / 650.0;
        let per_base_1000 = t(1000) / 1000.0;
        // Near-linear to 650:
        assert!(per_base_650 / per_base_150 < 1.5, "650: {per_base_650} vs {per_base_150}");
        // Super-linear by 1000:
        assert!(per_base_1000 / per_base_650 > 1.15, "1000: {per_base_1000} vs {per_base_650}");
    }

    #[test]
    fn protein_lut_benefit_is_partial() {
        // Σ=20 overflows the 36-entry LUT (§4.3), so disabling LUTs hurts
        // less than for DNA.
        let dna = dna_training();
        let mut pro = Workload::protein_canonical();
        pro.steps = StepKind::Training; // isolate the LUT effect
        let factor = |wl: &Workload| {
            let all = cycles(&AccelConfig::default(), wl).total();
            let mut cfg = AccelConfig::default();
            cfg.opt.luts = false;
            cycles(&cfg, wl).total() / all
        };
        assert!(factor(&dna) > factor(&pro));
    }

    #[test]
    fn forward_only_skips_backward_and_update() {
        let mut wl = dna_training();
        wl.steps = StepKind::Forward;
        let bd = cycles(&AccelConfig::default(), &wl);
        assert_eq!(bd.backward, 0.0);
        assert_eq!(bd.update, 0.0);
        assert!(bd.forward > 0.0);
    }

    #[test]
    fn more_ports_relieve_memory_bound() {
        let wl = dna_training();
        let mut cfg = AccelConfig::default();
        cfg.opt.luts = false; // force memory-bound
        let slow = cycles(&cfg, &wl).total();
        cfg.mem_ports = 32;
        let fast = cycles(&cfg, &wl).total();
        assert!(fast < slow * 0.5);
    }
}
