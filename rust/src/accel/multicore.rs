//! Multi-core end-to-end scaling model (Fig. 9).
//!
//! The paper splits application time into (1) the CPU part that ApHMM
//! does not accelerate, (2) the Baum-Welch part running on N cores, and
//! (3) data-movement overhead that *grows* with core count (DMA fan-out,
//! DRAM contention).  The observed optimum is 4 cores: beyond that the
//! movement overhead outgrows the shrinking Baum-Welch share.

use super::config::AccelConfig;
use super::perf::cycles;
use super::workload::Workload;

/// End-to-end application split (measured on the real Rust apps).
#[derive(Clone, Copy, Debug)]
pub struct AppSplit {
    /// Seconds of non-Baum-Welch CPU work (not accelerated).
    pub cpu_other_s: f64,
    /// Seconds of Baum-Welch work on the single-thread CPU baseline.
    pub cpu_bw_s: f64,
}

/// Multi-core runtime estimate.
#[derive(Clone, Copy, Debug)]
pub struct MulticoreResult {
    /// Cores used.
    pub n_cores: usize,
    /// Remaining CPU seconds.
    pub cpu_s: f64,
    /// Accelerated Baum-Welch seconds.
    pub accel_s: f64,
    /// Data-movement overhead seconds.
    pub movement_s: f64,
}

impl MulticoreResult {
    /// Total end-to-end seconds.
    pub fn total(&self) -> f64 {
        self.cpu_s + self.accel_s + self.movement_s
    }
}

/// Per-core DMA/orchestration overhead as a fraction of the single-core
/// accelerated time (calibrated so 4 cores is the Fig. 9 optimum for the
/// error-correction split of Fig. 2).
const MOVEMENT_PER_CORE: f64 = 0.18;

/// Effective parallel efficiency per added core (DRAM contention).
const PARALLEL_EFFICIENCY: f64 = 0.92;

/// Estimate the end-to-end runtime of an application on `n_cores` ApHMM
/// cores, given its measured split and the accelerator workload.
pub fn multicore_runtime(
    cfg: &AccelConfig,
    wl: &Workload,
    split: &AppSplit,
    n_cores: usize,
) -> MulticoreResult {
    let single = cycles(cfg, wl).seconds(cfg);
    let eff = PARALLEL_EFFICIENCY.powi(n_cores.saturating_sub(1) as i32);
    let accel_s = single / (n_cores as f64 * eff);
    let movement_s = single * MOVEMENT_PER_CORE * (n_cores as f64).ln_1p();
    MulticoreResult { n_cores, cpu_s: split.cpu_other_s, accel_s, movement_s }
}

/// Find the best core count in `1..=max` for an application.  Among
/// counts within 2 % of the minimum total, the smallest wins (extra
/// cores cost area/power for no measurable speedup — the paper's reason
/// for settling on 4 cores over 8).
pub fn best_core_count(cfg: &AccelConfig, wl: &Workload, split: &AppSplit, max: usize) -> usize {
    let times: Vec<(usize, f64)> =
        (1..=max).map(|c| (c, multicore_runtime(cfg, wl, split, c).total())).collect();
    let best = times.iter().map(|&(_, t)| t).fold(f64::INFINITY, f64::min);
    times
        .iter()
        .find(|&&(_, t)| t <= best * 1.02)
        .map(|&(c, _)| c)
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ec_split(cfg: &AccelConfig, wl: &Workload) -> AppSplit {
        // Error correction: Baum-Welch is 98.57 % of CPU time (Fig. 2).
        let single = cycles(cfg, wl).seconds(cfg);
        let cpu_bw = single * 40.0; // CPU ~40x slower than one core
        AppSplit { cpu_other_s: cpu_bw * (1.0 - 0.9857) / 0.9857, cpu_bw_s: cpu_bw }
    }

    #[test]
    fn four_cores_near_optimal_for_error_correction() {
        let cfg = AccelConfig::default();
        let wl = Workload::ec_canonical();
        let split = ec_split(&cfg, &wl);
        let best = best_core_count(&cfg, &wl, &split, 8);
        assert!((2..=6).contains(&best), "best={best}");
        // And 8 cores must not beat 4 (the Fig. 9 observation).
        let t4 = multicore_runtime(&cfg, &wl, &split, 4).total();
        let t8 = multicore_runtime(&cfg, &wl, &split, 8).total();
        assert!(t8 >= t4 * 0.95, "t4={t4} t8={t8}");
    }

    #[test]
    fn movement_overhead_grows_with_cores() {
        let cfg = AccelConfig::default();
        let wl = Workload::ec_canonical();
        let split = ec_split(&cfg, &wl);
        let m2 = multicore_runtime(&cfg, &wl, &split, 2).movement_s;
        let m8 = multicore_runtime(&cfg, &wl, &split, 8).movement_s;
        assert!(m8 > m2);
    }

    #[test]
    fn accel_time_shrinks_with_cores() {
        let cfg = AccelConfig::default();
        let wl = Workload::ec_canonical();
        let split = ec_split(&cfg, &wl);
        let a1 = multicore_runtime(&cfg, &wl, &split, 1).accel_s;
        let a4 = multicore_runtime(&cfg, &wl, &split, 4).accel_s;
        assert!(a4 < a1 / 2.5);
    }

    #[test]
    fn cpu_dominated_apps_prefer_fewer_cores() {
        // Protein search: only 45.76 % of time is Baum-Welch, so extra
        // cores buy little.
        let cfg = AccelConfig::default();
        let wl = Workload::protein_canonical();
        let single = cycles(&cfg, &wl).seconds(&cfg);
        let split = AppSplit { cpu_other_s: single * 100.0, cpu_bw_s: single * 80.0 };
        let t1 = multicore_runtime(&cfg, &wl, &split, 1).total();
        let t8 = multicore_runtime(&cfg, &wl, &split, 8).total();
        // Nearly flat: the unaccelerated part dominates.
        assert!((t8 - t1).abs() / t1 < 0.05);
    }
}
