//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the ApHMM library.
#[derive(Error, Debug)]
pub enum ApHmmError {
    /// Input sequence contains a character outside the active alphabet.
    #[error("invalid character {ch:?} for alphabet {alphabet}")]
    InvalidCharacter { ch: char, alphabet: &'static str },

    /// A pHMM graph failed a structural invariant.
    #[error("invalid pHMM graph: {0}")]
    InvalidGraph(String),

    /// Banded encoding constraint violated (e.g. backward transition).
    #[error("banded encoding error: {0}")]
    Banded(String),

    /// Numerical failure (all-zero forward row, likelihood underflow...).
    #[error("numerical failure: {0}")]
    Numerical(String),

    /// Configuration file / CLI parameter problem.
    #[error("config error: {0}")]
    Config(String),

    /// Malformed input file (FASTA/FASTQ/profile/manifest).
    #[error("parse error in {path}: {msg}")]
    Parse { path: String, msg: String },

    /// PJRT runtime failure (artifact loading, compilation, execution).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator scheduling / channel failure.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for ApHmmError {
    fn from(e: xla::Error) -> Self {
        ApHmmError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, ApHmmError>;
