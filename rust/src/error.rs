//! Crate-wide error type.
//!
//! Hand-written `Display`/`Error` impls (no `thiserror`): the crate
//! builds offline with zero dependencies.

use std::fmt;

/// Why a request was abandoned before completion (see
/// [`ApHmmError::Cancelled`] and the `cancel` module).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelCause {
    /// The submitter cancelled the request explicitly.
    Cancelled,
    /// The request's deadline passed before it completed.
    DeadlineExceeded,
}

impl fmt::Display for CancelCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CancelCause::Cancelled => write!(f, "request cancelled"),
            CancelCause::DeadlineExceeded => write!(f, "deadline exceeded"),
        }
    }
}

/// Errors surfaced by the ApHMM library.
#[derive(Debug)]
pub enum ApHmmError {
    /// Input sequence contains a character outside the active alphabet.
    InvalidCharacter {
        /// Offending character.
        ch: char,
        /// Alphabet name.
        alphabet: &'static str,
    },

    /// A pHMM graph failed a structural invariant.
    InvalidGraph(String),

    /// Banded encoding constraint violated (e.g. backward transition).
    Banded(String),

    /// Numerical failure (all-zero forward row, likelihood underflow...).
    Numerical(String),

    /// Configuration file / CLI parameter problem.
    Config(String),

    /// Malformed input file (FASTA/FASTQ/profile/manifest).
    Parse {
        /// File that failed to parse.
        path: String,
        /// What went wrong.
        msg: String,
    },

    /// PJRT runtime failure (artifact loading, compilation, execution).
    Runtime(String),

    /// Coordinator scheduling / channel failure.
    Coordinator(String),

    /// The request was cancelled or its deadline expired before it
    /// completed.  Aborts the whole request at a cooperative check —
    /// never a partial result, so completed requests stay
    /// bit-identical to uncancelled runs.
    Cancelled(CancelCause),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for ApHmmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApHmmError::InvalidCharacter { ch, alphabet } => {
                write!(f, "invalid character {ch:?} for alphabet {alphabet}")
            }
            ApHmmError::InvalidGraph(m) => write!(f, "invalid pHMM graph: {m}"),
            ApHmmError::Banded(m) => write!(f, "banded encoding error: {m}"),
            ApHmmError::Numerical(m) => write!(f, "numerical failure: {m}"),
            ApHmmError::Config(m) => write!(f, "config error: {m}"),
            ApHmmError::Parse { path, msg } => write!(f, "parse error in {path}: {msg}"),
            ApHmmError::Runtime(m) => write!(f, "runtime error: {m}"),
            ApHmmError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            ApHmmError::Cancelled(cause) => write!(f, "{cause}"),
            ApHmmError::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ApHmmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ApHmmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ApHmmError {
    fn from(e: std::io::Error) -> Self {
        ApHmmError::Io(e)
    }
}

// Gated on `pjrt` (not the stub-compatible `xla` feature): the `xla`
// crate only exists in vendored `pjrt` builds.
#[cfg(feature = "pjrt")]
impl From<xla::Error> for ApHmmError {
    fn from(e: xla::Error) -> Self {
        ApHmmError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, ApHmmError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_descriptive() {
        let e = ApHmmError::InvalidGraph("bad row".into());
        assert_eq!(e.to_string(), "invalid pHMM graph: bad row");
        let e = ApHmmError::Parse { path: "x.fa".into(), msg: "line 3".into() };
        assert_eq!(e.to_string(), "parse error in x.fa: line 3");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: ApHmmError = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
