//! Step-level timing shared by the three applications.

/// Wall-time breakdown of an application run, split the way Fig. 2
/// splits it: the three Baum-Welch steps vs everything else.
#[derive(Clone, Copy, Debug, Default)]
pub struct AppTimings {
    /// Forward-calculation nanoseconds.
    pub forward_ns: u128,
    /// Backward + parameter-update nanoseconds (fused pass).
    pub backward_update_ns: u128,
    /// Maximization nanoseconds.
    pub maximize_ns: u128,
    /// Non-Baum-Welch nanoseconds (graph construction, I/O, decode,
    /// mapping, pre-filters...).
    pub other_ns: u128,
}

impl AppTimings {
    /// Total nanoseconds.
    pub fn total_ns(&self) -> u128 {
        self.forward_ns + self.backward_update_ns + self.maximize_ns + self.other_ns
    }

    /// Fraction of time inside the Baum-Welch algorithm (Fig. 2's
    /// headline statistic: 45.76 % – 98.57 %).
    pub fn bw_fraction(&self) -> f64 {
        let total = self.total_ns();
        if total == 0 {
            return 0.0;
        }
        (self.forward_ns + self.backward_update_ns + self.maximize_ns) as f64 / total as f64
    }

    /// Merge another timing block.
    pub fn merge(&mut self, other: &AppTimings) {
        self.forward_ns += other.forward_ns;
        self.backward_update_ns += other.backward_update_ns;
        self.maximize_ns += other.maximize_ns;
        self.other_ns += other.other_ns;
    }

    /// Seconds split `(bw, other)` — the Fig. 9 [`crate::accel::AppSplit`]
    /// inputs.
    pub fn split_seconds(&self) -> (f64, f64) {
        (
            (self.forward_ns + self.backward_update_ns + self.maximize_ns) as f64 / 1e9,
            self.other_ns as f64 / 1e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_and_merge() {
        let mut a = AppTimings { forward_ns: 50, backward_update_ns: 30, maximize_ns: 10, other_ns: 10 };
        assert!((a.bw_fraction() - 0.9).abs() < 1e-12);
        let b = AppTimings { other_ns: 100, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total_ns(), 200);
        assert!((a.bw_fraction() - 0.45).abs() < 1e-12);
    }

    #[test]
    fn empty_timings_are_zero() {
        assert_eq!(AppTimings::default().bw_fraction(), 0.0);
    }
}
