//! Use case 2 — protein family search (hmmsearch, §2.3 / §5.5).
//!
//! A family database holds one folded traditional-design pHMM per family
//! (the role Pfam's `.hmm` files play).  A query is first screened by a
//! cheap k-mer containment pre-filter (the role of HMMER's MSV/SSV
//! pipeline stages — this is the "non-Baum-Welch" part of Fig. 2's
//! hmmsearch profile), and the surviving families are scored through the
//! database's [`ExpectationEngine`] (log-odds vs a uniform null model).
//!
//! Database profiles are frozen, so each family's engine state is
//! prepared once at load time ([`ExpectationEngine::prepare`] — the
//! fused coefficient tables of the sparse engine, the banded encoding
//! of the dense one) and every query scores through it (paper §4.2
//! applied to search).  [`FamilyDb`] defaults to the sparse engine;
//! [`FamilyDb::build_with`] accepts any backend.

use std::collections::HashSet;
use std::time::Instant;

use crate::baumwelch::{
    train_with_engine, ExpectationEngine, FilterConfig, ForwardOptions, SparseEngine, TrainConfig,
    TrainMode,
};
use crate::error::Result;
use crate::phmm::{Phmm, Profile, TraditionalParams};
use crate::seq::{Alphabet, Sequence};
use crate::sim::ProteinFamily;

use super::timing::AppTimings;

/// Search configuration.
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    /// k-mer size of the pre-filter screen.
    pub prefilter_k: usize,
    /// Minimum shared-k-mer fraction to run the full Forward scoring
    /// (0 disables the pre-filter, scoring every family).
    pub prefilter_min_frac: f64,
    /// State filter during scoring (sparse engine; dense engines
    /// ignore it).
    pub filter: FilterConfig,
    /// Report the top `max_hits` families.
    pub max_hits: usize,
    /// Run posterior decoding (Backward pass) on the top `posterior_hits`
    /// hits — the analogue of hmmsearch's domain post-processing stage,
    /// which is why Fig. 2 shows Backward time for the search use case.
    pub posterior_hits: usize,
    /// Traditional-design transition parameters for database profiles.
    pub params: TraditionalParams,
    /// Silent-state folding depth.
    pub fold_depth: usize,
    /// Baum-Welch refinement epochs run per family profile on its
    /// members at build time (what `hmmbuild`'s EM polishing does);
    /// `0` keeps the raw column-counted profiles.
    pub refine_iters: usize,
    /// Training schedule of that refinement.  [`TrainMode::Auto`]
    /// trains small member sets full-batch and large ones minibatch.
    pub mode: TrainMode,
    /// Shuffle seed of the minibatch refinement schedule.
    pub seed: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            prefilter_k: 3,
            prefilter_min_frac: 0.08,
            filter: FilterConfig::None,
            max_hits: 10,
            posterior_hits: 3,
            params: TraditionalParams::default(),
            fold_depth: 4,
            refine_iters: 0,
            mode: TrainMode::Auto,
            seed: 1,
        }
    }
}

/// One family profile in the database.
pub struct FamilyEntry<E: ExpectationEngine = SparseEngine> {
    /// Family identifier.
    pub id: String,
    /// Folded (emitting-only) pHMM.
    pub phmm: Phmm,
    /// k-mer set of the family consensus (pre-filter).
    kmers: HashSet<u64>,
    /// Frozen engine state for the profile — database profiles never
    /// change, so it is built once per family at load time and every
    /// query scores through it.
    prepared: E::Prepared,
}

/// A database of family pHMMs (the Pfam stand-in), scored through one
/// [`ExpectationEngine`].
pub struct FamilyDb<E: ExpectationEngine = SparseEngine> {
    /// Profiles, indexed by family.
    pub entries: Vec<FamilyEntry<E>>,
    engine: E,
    alphabet: Alphabet,
    k: usize,
}

/// A scored hit.
#[derive(Clone, Debug)]
pub struct SearchHit {
    /// Family identifier.
    pub family: String,
    /// Length-normalized log-odds score (bits-like).
    pub score: f64,
}

/// Result of searching one query (or a batch).
#[derive(Clone, Debug, Default)]
pub struct SearchReport {
    /// Ranked hits (best first).
    pub hits: Vec<SearchHit>,
    /// Families passing the pre-filter / total.
    pub scored: usize,
    /// Timings (Fig. 2: Forward scoring vs pre-filter+overheads).
    pub timings: AppTimings,
}

/// Length-normalized log-odds score of a forward log-likelihood
/// against an i.i.d. uniform null model over `sigma` symbols — the
/// score unit shared by [`FamilyDb::search`] and the serving layer's
/// `Score`/`Search` responses (hmmsearch uses a background model;
/// uniform keeps scores comparable here).
pub fn log_odds_score(loglik: f64, len: usize, sigma: usize) -> f64 {
    let len = len.max(1) as f64;
    let null_per_residue = -(sigma as f64).ln();
    (loglik - null_per_residue * len) / len
}

/// The k-mer containment set of a sequence (encoded symbols), used by
/// the MSV/SSV-style pre-filter of [`FamilyDb::search`] and the serving
/// layer's `Search` requests.
pub fn kmer_set(seq: &[u8], k: usize, sigma: usize) -> HashSet<u64> {
    let mut set = HashSet::new();
    if seq.len() < k {
        return set;
    }
    for win in seq.windows(k) {
        let mut key = 0u64;
        for &c in win {
            key = key * sigma as u64 + c as u64;
        }
        set.insert(key);
    }
    set
}

impl FamilyDb<SparseEngine> {
    /// Build the database from simulated families on the default sparse
    /// engine: column-counted profiles of the members (what `hmmbuild`
    /// would produce), lowered to folded traditional pHMMs.
    pub fn build(
        families: &[ProteinFamily],
        alphabet: Alphabet,
        cfg: &SearchConfig,
    ) -> Result<FamilyDb<SparseEngine>> {
        FamilyDb::build_with(SparseEngine, families, alphabet, cfg)
    }
}

impl<E: ExpectationEngine> FamilyDb<E> {
    /// [`FamilyDb::build`] on an explicit engine backend.
    pub fn build_with(
        engine: E,
        families: &[ProteinFamily],
        alphabet: Alphabet,
        cfg: &SearchConfig,
    ) -> Result<FamilyDb<E>> {
        let mut entries = Vec::with_capacity(families.len());
        for fam in families {
            let profile =
                Profile::from_members(&fam.members, fam.ancestor.len(), alphabet, 0.5);
            let mut phmm =
                Phmm::traditional(&profile, &cfg.params)?.fold_silent(cfg.fold_depth)?;
            if cfg.refine_iters > 0 {
                // EM-polish the profile on its own members before
                // freezing (hmmbuild's refinement step); the schedule
                // layer picks batch vs minibatch per member-set size.
                let tcfg = TrainConfig {
                    max_iters: cfg.refine_iters,
                    tol: 0.0,
                    filter: cfg.filter,
                    mode: cfg.mode,
                    seed: cfg.seed,
                    ..Default::default()
                };
                train_with_engine(
                    &engine,
                    &mut phmm,
                    &fam.members,
                    &tcfg,
                    crate::pool::WorkerPool::global(),
                )?;
            }
            let kmers = kmer_set(&fam.ancestor.data, cfg.prefilter_k, alphabet.size());
            let prepared = engine.prepare(&phmm)?;
            entries.push(FamilyEntry { id: fam.id.clone(), phmm, kmers, prepared });
        }
        Ok(FamilyDb { entries, engine, alphabet, k: cfg.prefilter_k })
    }

    /// Number of families.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the database is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Search one query sequence against the database.
    pub fn search(&self, query: &Sequence, cfg: &SearchConfig) -> Result<SearchReport> {
        let mut report = SearchReport::default();
        let sigma = self.alphabet.size();

        // ---- Pre-filter (non-BW) ----
        let t0 = Instant::now();
        let qk = kmer_set(&query.data, self.k, sigma);
        let mut candidates: Vec<usize> = Vec::new();
        for (i, entry) in self.entries.iter().enumerate() {
            if cfg.prefilter_min_frac <= 0.0 {
                candidates.push(i);
                continue;
            }
            let shared = qk.intersection(&entry.kmers).count();
            let frac = shared as f64 / qk.len().max(1) as f64;
            if frac >= cfg.prefilter_min_frac {
                candidates.push(i);
            }
        }
        report.timings.other_ns += t0.elapsed().as_nanos();

        // ---- Forward scoring (BW) ----
        // One scratch reused across the whole candidate list (the
        // sparse engine's buffers grow to the largest profile), each
        // family scored through its frozen engine state.
        let opts = ForwardOptions { filter: cfg.filter, ..Default::default() };
        let mut scratch: Option<E::Scratch> = None;
        let mut hits: Vec<SearchHit> = Vec::new();
        for &i in &candidates {
            let entry = &self.entries[i];
            let scratch = scratch.get_or_insert_with(|| self.engine.make_scratch(&entry.phmm));
            let t1 = Instant::now();
            let ll = match self.engine.score(&entry.phmm, &entry.prepared, query, &opts, scratch)
            {
                Ok(res) => res.loglik,
                Err(_) => {
                    report.timings.forward_ns += t1.elapsed().as_nanos();
                    continue;
                }
            };
            report.timings.forward_ns += t1.elapsed().as_nanos();
            let score = log_odds_score(ll, query.len(), sigma);
            hits.push(SearchHit { family: entry.id.clone(), score });
        }
        let t2 = Instant::now();
        hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        hits.truncate(cfg.max_hits);
        report.scored = candidates.len();
        report.timings.other_ns += t2.elapsed().as_nanos();

        // ---- Posterior decoding of the top hits (BW: Backward) ----
        // hmmsearch runs Forward AND Backward for its reported domains
        // (the paper's Fig. 2 shows both for this use case); we run the
        // engine's full expectation pass for the best `posterior_hits`
        // families.
        for hit in hits.iter().take(cfg.posterior_hits) {
            if let Some(entry) = self.entries.iter().find(|e| e.id == hit.family) {
                let scratch = scratch.get_or_insert_with(|| self.engine.make_scratch(&entry.phmm));
                let mut acc = self.engine.make_acc(&entry.phmm);
                if let Ok(stats) = self.engine.accumulate_read(
                    &entry.phmm,
                    &entry.prepared,
                    query,
                    &opts,
                    scratch,
                    &mut acc,
                ) {
                    report.timings.forward_ns += stats.forward_ns;
                    report.timings.backward_update_ns += stats.backward_update_ns;
                }
            }
        }
        report.hits = hits;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::BandedEngine;
    use crate::seq::PROTEIN;
    use crate::sim::{generate_families, ProteinSimParams, XorShift};

    fn db(rng: &mut XorShift, n: usize) -> (Vec<ProteinFamily>, FamilyDb, SearchConfig) {
        let params = ProteinSimParams { n_families: n, ..Default::default() };
        let fams = generate_families(rng, &params);
        let cfg = SearchConfig::default();
        let db = FamilyDb::build(&fams, PROTEIN, &cfg).unwrap();
        (fams, db, cfg)
    }

    #[test]
    fn members_find_their_family() {
        let mut rng = XorShift::new(11);
        let (fams, db, cfg) = db(&mut rng, 12);
        let mut correct = 0;
        let mut total = 0;
        for fam in fams.iter().take(6) {
            for member in fam.members.iter().take(2) {
                total += 1;
                let report = db.search(member, &cfg).unwrap();
                if let Some(top) = report.hits.first() {
                    if top.family == fam.id {
                        correct += 1;
                    }
                }
            }
        }
        assert!(correct as f64 >= total as f64 * 0.8, "{correct}/{total}");
    }

    #[test]
    fn prefilter_reduces_scored_families() {
        let mut rng = XorShift::new(12);
        let (fams, db, cfg) = db(&mut rng, 16);
        let query = &fams[0].members[0];
        let filtered = db.search(query, &cfg).unwrap();
        let mut unfiltered_cfg = cfg;
        unfiltered_cfg.prefilter_min_frac = 0.0;
        let unfiltered = db.search(query, &unfiltered_cfg).unwrap();
        assert!(filtered.scored < unfiltered.scored, "{} vs {}", filtered.scored, unfiltered.scored);
        assert_eq!(unfiltered.scored, db.len());
        // Pre-filtering must not lose the true family.
        assert_eq!(filtered.hits[0].family, fams[0].id);
    }

    #[test]
    fn forward_dominates_but_less_than_error_correction() {
        // Fig. 2: hmmsearch ≈46 % Baum-Welch — lower than error
        // correction because of the pre-filter pipeline.  Exact numbers
        // are machine-dependent; assert the forward share is substantial
        // but the pre-filter is visible.
        let mut rng = XorShift::new(13);
        let (fams, db, cfg) = db(&mut rng, 16);
        let mut timings = AppTimings::default();
        for fam in fams.iter().take(4) {
            let report = db.search(&fam.members[0], &cfg).unwrap();
            timings.merge(&report.timings);
        }
        let f = timings.bw_fraction();
        assert!(f > 0.2, "bw fraction {f}");
        assert!(timings.other_ns > 0);
    }

    #[test]
    fn banded_backend_ranks_like_sparse() {
        // The database is generic over the engine: the banded backend
        // must agree with the sparse default on the top hit (scores
        // differ only by f32 rounding).
        let mut rng = XorShift::new(15);
        let params = ProteinSimParams { n_families: 8, ..Default::default() };
        let fams = generate_families(&mut rng, &params);
        let cfg = SearchConfig::default();
        let sparse_db = FamilyDb::build(&fams, PROTEIN, &cfg).unwrap();
        let banded_db = FamilyDb::build_with(BandedEngine, &fams, PROTEIN, &cfg).unwrap();
        for fam in fams.iter().take(3) {
            let query = &fam.members[0];
            let a = sparse_db.search(query, &cfg).unwrap();
            let b = banded_db.search(query, &cfg).unwrap();
            assert_eq!(a.scored, b.scored);
            assert_eq!(
                a.hits.first().map(|h| h.family.clone()),
                b.hits.first().map(|h| h.family.clone()),
                "query {}",
                query.id
            );
        }
    }

    #[test]
    fn scores_are_length_normalized() {
        let mut rng = XorShift::new(14);
        let (fams, db, cfg) = db(&mut rng, 8);
        let report = db.search(&fams[0].members[0], &cfg).unwrap();
        for hit in &report.hits {
            assert!(hit.score.abs() < 10.0, "unnormalized score {}", hit.score);
        }
    }

    #[test]
    fn refined_profiles_still_rank_members_first() {
        // Build-time EM refinement (any schedule) must not break family
        // recognition; run one epoch of each mode through the generic
        // build path.
        let mut rng = XorShift::new(19);
        let params = ProteinSimParams { n_families: 6, ..Default::default() };
        let fams = generate_families(&mut rng, &params);
        for mode in [TrainMode::Batch, TrainMode::Minibatch, TrainMode::Viterbi] {
            let cfg = SearchConfig { refine_iters: 1, mode, ..Default::default() };
            let db = FamilyDb::build(&fams, PROTEIN, &cfg).unwrap();
            let mut correct = 0;
            let mut total = 0;
            for fam in fams.iter().take(4) {
                total += 1;
                let report = db.search(&fam.members[0], &cfg).unwrap();
                if report.hits.first().map(|h| h.family.as_str()) == Some(fam.id.as_str()) {
                    correct += 1;
                }
            }
            assert!(
                correct as f64 >= total as f64 * 0.7,
                "mode {mode:?}: {correct}/{total}"
            );
        }
    }

    #[test]
    fn empty_db_returns_no_hits() {
        let db = FamilyDb::build(&[], PROTEIN, &SearchConfig::default()).unwrap();
        let q = Sequence::from_str("q", "ACDEFGHIKL", PROTEIN).unwrap();
        let report = db.search(&q, &SearchConfig::default()).unwrap();
        assert!(report.hits.is_empty());
        assert!(db.is_empty());
    }
}
