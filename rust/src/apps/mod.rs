//! The paper's three end-to-end use cases (§2.3, §5.4–5.6), each
//! instrumented with step-level timing so the Fig. 2 breakdown and the
//! Fig. 9/11 application splits come from real measurements.

mod error_correction;
mod msa;
mod protein_search;
mod timing;

pub use error_correction::{
    correct_assembly, train_chunk, train_chunk_with, ChunkTrainOutcome, CorrectionConfig,
    CorrectionReport,
};
pub use msa::{
    align_all, align_all_streamed, align_all_streamed_with, align_all_with, msa_identity,
    posterior_columns, profile_columns, AlignedRow, MsaConfig, MsaReport,
};
pub use protein_search::{
    kmer_set, log_odds_score, FamilyDb, FamilyEntry, SearchConfig, SearchHit, SearchReport,
};
pub use timing::AppTimings;
