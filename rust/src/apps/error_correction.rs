//! Use case 1 — error correction (Apollo, §2.3 / §5.4).
//!
//! Pipeline: chunk the assembly (§Supplemental S2: 150–1000 base
//! chunks), map reads to chunks with the minimizer mapper, build one
//! EC-design pHMM per chunk, train it with the mapped read segments
//! (Baum-Welch + state filter), decode the Viterbi consensus, and
//! concatenate the corrected chunks.

use std::time::Instant;

use crate::baumwelch::{
    train_in_with, EngineKind, FilterConfig, ScratchMode, TrainConfig, TrainMode, TrainResult,
};
use crate::cancel::CancelToken;
use crate::error::Result;
use crate::mapper::{MapperConfig, MinimizerIndex};
use crate::phmm::{EcDesignParams, Phmm};
use crate::pool::WorkerPool;
use crate::seq::Sequence;
use crate::viterbi::consensus;

use super::timing::AppTimings;

/// One trained chunk: the decoded consensus plus the training
/// instrumentation and the non-Baum-Welch build/decode times.
#[derive(Clone, Debug)]
pub struct ChunkTrainOutcome {
    /// Viterbi consensus of the trained graph.
    pub consensus: Sequence,
    /// Training result and instrumentation.
    pub train: TrainResult,
    /// Graph construction time (ns).
    pub build_ns: u128,
    /// Consensus decode time (ns).
    pub decode_ns: u128,
}

/// Build an EC-design pHMM for `reference` (over `alphabet`), train it
/// on `reads`, and decode the Viterbi consensus — the chunk-level
/// primitive shared by the batch corrector below, the coordinator's
/// streaming chunk jobs, and the serving layer's `Correct` requests.
pub fn train_chunk(
    reference: &Sequence,
    reads: &[Sequence],
    design: &EcDesignParams,
    alphabet: crate::seq::Alphabet,
    train_cfg: &TrainConfig,
    pool: &WorkerPool,
) -> Result<ChunkTrainOutcome> {
    train_chunk_with(reference, reads, design, alphabet, train_cfg, pool, &CancelToken::none())
}

/// [`train_chunk`] with a cooperative [`CancelToken`], observed at each
/// per-read E-step boundary.  A fired token aborts the whole chunk with
/// [`crate::error::ApHmmError::Cancelled`]; chunks that complete are
/// bit-identical to untokened runs.
pub fn train_chunk_with(
    reference: &Sequence,
    reads: &[Sequence],
    design: &EcDesignParams,
    alphabet: crate::seq::Alphabet,
    train_cfg: &TrainConfig,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<ChunkTrainOutcome> {
    let t0 = Instant::now();
    let mut graph = Phmm::error_correction_for(reference, design, alphabet)?;
    let build_ns = t0.elapsed().as_nanos();
    let train = train_in_with(&mut graph, reads, train_cfg, pool, cancel)?;
    let t1 = Instant::now();
    let decoded = consensus(&graph)?;
    let decode_ns = t1.elapsed().as_nanos();
    Ok(ChunkTrainOutcome { consensus: decoded.consensus, train, build_ns, decode_ns })
}

/// Error-correction configuration.
#[derive(Clone, Copy, Debug)]
pub struct CorrectionConfig {
    /// Chunk length in bases (the paper's sweet spot: 650).
    pub chunk_len: usize,
    /// EC pHMM design parameters.
    pub design: EcDesignParams,
    /// EM iterations per chunk.
    pub max_iters: usize,
    /// State filter (Apollo uses best-500; histogram is ApHMM's mode).
    pub filter: FilterConfig,
    /// Minimum mapped reads to attempt correction of a chunk.
    pub min_reads: usize,
    /// Extra read bases taken past the lifted chunk end when slicing.
    /// Keep at 0 with anchor-lifted mapping: every surplus base piles up
    /// in the insertion chain of the final positions and trains phantom
    /// insertions into the consensus (measured: +9 bases of bloat per
    /// chunk at margin 12).
    pub margin: usize,
    /// Mapper settings.
    pub mapper: MapperConfig,
    /// E-step worker threads per chunk (1 = single-threaded).  Results
    /// are bit-identical for any value; raise it when correcting few
    /// large chunks rather than many small ones (which parallelize
    /// better at the chunk/coordinator level).  Parallelism draws from
    /// the process-wide shared [`WorkerPool`].
    pub estep_workers: usize,
    /// Baum-Welch backend used to train each chunk.
    pub engine: EngineKind,
    /// Forward-scratch policy for training.  The long-read default is
    /// [`ScratchMode::Auto`]: normal chunk segments (≈`chunk_len`
    /// bases) resolve to the full matrix, while an ultra-long segment
    /// whose full matrix would exceed [`max_scratch_bytes`] trains
    /// checkpointed — bit-identical output, O(√T·states) peak scratch.
    ///
    /// [`max_scratch_bytes`]: CorrectionConfig::max_scratch_bytes
    pub scratch_mode: ScratchMode,
    /// Per-read forward-scratch budget (bytes) that `Auto` resolves
    /// against.  The default (256 MiB) never triggers on paper-scale
    /// 650-base chunks; it exists to keep nanopore-length segments
    /// from materializing multi-gigabyte matrices.
    pub max_scratch_bytes: usize,
    /// Training schedule per chunk.  The default stays
    /// [`TrainMode::Batch`] — chunk read sets are small and the
    /// correctness contract (`estep_workers` unobservable, byte-stable
    /// consensus) is pinned to full-batch EM; switch to
    /// [`TrainMode::Minibatch`] or [`TrainMode::Viterbi`] for very deep
    /// coverage.
    pub mode: TrainMode,
    /// Shuffle seed of the minibatch schedule (ignored by `Batch`).
    pub seed: u64,
}

impl Default for CorrectionConfig {
    fn default() -> Self {
        CorrectionConfig {
            chunk_len: 650,
            design: EcDesignParams::default(),
            max_iters: 2,
            filter: FilterConfig::histogram_default(),
            min_reads: 3,
            margin: 0,
            mapper: MapperConfig::default(),
            estep_workers: 1,
            engine: EngineKind::Sparse,
            scratch_mode: ScratchMode::Auto,
            max_scratch_bytes: 256 << 20,
            mode: TrainMode::Batch,
            seed: 1,
        }
    }
}

/// Output of a correction run.
#[derive(Clone, Debug)]
pub struct CorrectionReport {
    /// The corrected assembly.
    pub corrected: Sequence,
    /// Chunks processed / chunks actually trained.
    pub chunks_total: usize,
    /// Chunks with enough coverage to train.
    pub chunks_trained: usize,
    /// Reads that mapped to the assembly.
    pub reads_mapped: usize,
    /// Step-level timings (Fig. 2).
    pub timings: AppTimings,
    /// Accelerator workload counters aggregated over chunks.
    pub states_processed: u64,
    /// Edge traversals aggregated over chunks.
    pub edges_processed: u64,
    /// Total Baum-Welch timesteps.
    pub timesteps: u64,
    /// Read segments skipped during training (numerically dead),
    /// aggregated over chunks and EM iterations.
    pub reads_skipped: u64,
    /// Highest per-read forward-row scratch any chunk reached (bytes;
    /// high-water mark across chunks, not a sum).
    pub peak_scratch_bytes: u64,
}

/// Run Apollo-style error correction of `assembly` using `reads`.
pub fn correct_assembly(
    assembly: &Sequence,
    reads: &[Sequence],
    cfg: &CorrectionConfig,
) -> Result<CorrectionReport> {
    let mut timings = AppTimings::default();
    // One shared pool per app session: every chunk's E-step fan-out
    // draws helpers from it instead of spawning fresh scoped threads.
    let pool = WorkerPool::global();

    // ---- Mapping (non-BW time) ----
    let t0 = Instant::now();
    let index = MinimizerIndex::build(assembly, cfg.mapper);
    let mut placements: Vec<(usize, crate::mapper::Mapping)> = Vec::new();
    for (ri, read) in reads.iter().enumerate() {
        if let Some(m) = index.map(read) {
            placements.push((ri, m));
        }
    }
    timings.other_ns += t0.elapsed().as_nanos();
    let reads_mapped = placements.len();

    let n_chunks = assembly.len().div_ceil(cfg.chunk_len.max(1));
    let mut corrected_parts: Vec<Sequence> = Vec::with_capacity(n_chunks);
    let mut chunks_trained = 0usize;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;
    let mut timesteps = 0u64;
    let mut reads_skipped = 0u64;
    let mut peak_scratch_bytes = 0u64;

    for c in 0..n_chunks {
        let lo = c * cfg.chunk_len;
        let hi = ((c + 1) * cfg.chunk_len).min(assembly.len());

        // ---- Gather read segments overlapping this chunk (non-BW) ----
        let t1 = Instant::now();
        let chunk_ref = assembly.slice(lo, hi);
        let mut segments: Vec<Sequence> = Vec::new();
        for (ri, m) in &placements {
            // Only reads that cover the chunk *start* can anchor at the
            // graph's initial states (Apollo anchors each read at its
            // aligned position; our chunk graphs anchor at position 0).
            // Reads ending inside the chunk are fine — the forward pass
            // may end anywhere in the graph.
            if m.ref_start <= lo && m.ref_end > lo {
                let read = &reads[*ri];
                // Lift the chunk bounds through the mapping anchors
                // (indel drift makes linear offsets wrong on long
                // noisy reads); small trailing margin for residual
                // drift — longer tails would train as phantom
                // insertions near the chunk end.
                let seg_start = m.lift_to_read(lo).min(read.len());
                let seg_end = (m.lift_to_read(hi) + cfg.margin).min(read.len());
                if seg_end > seg_start + 16 {
                    segments.push(read.slice(seg_start, seg_end));
                }
            }
        }
        timings.other_ns += t1.elapsed().as_nanos();

        if segments.len() < cfg.min_reads || chunk_ref.len() < 8 {
            corrected_parts.push(chunk_ref);
            continue;
        }

        // ---- Build + train + decode (the shared chunk primitive) ----
        let train_cfg = TrainConfig {
            max_iters: cfg.max_iters,
            tol: 1e-3,
            filter: cfg.filter,
            n_workers: cfg.estep_workers,
            engine: cfg.engine,
            scratch_mode: cfg.scratch_mode,
            max_scratch_bytes: cfg.max_scratch_bytes,
            mode: cfg.mode,
            seed: cfg.seed,
            ..Default::default()
        };
        let out =
            train_chunk(&chunk_ref, &segments, &cfg.design, crate::seq::DNA, &train_cfg, pool)?;
        let res = &out.train;
        timings.forward_ns += res.forward_ns;
        timings.backward_update_ns += res.backward_update_ns;
        timings.maximize_ns += res.maximize_ns;
        timings.other_ns += out.build_ns + out.decode_ns;
        states_processed += res.states_processed;
        edges_processed += res.edges_processed;
        timesteps += res.timesteps;
        reads_skipped += res.reads_skipped;
        peak_scratch_bytes = peak_scratch_bytes.max(res.peak_scratch_bytes);
        corrected_parts.push(out.consensus);
        chunks_trained += 1;
    }

    let mut data = Vec::with_capacity(assembly.len() + 64);
    for part in &corrected_parts {
        data.extend_from_slice(&part.data);
    }
    Ok(CorrectionReport {
        corrected: Sequence::from_symbols(format!("{}_corrected", assembly.id), data),
        chunks_total: n_chunks,
        chunks_trained,
        reads_mapped,
        timings,
        states_processed,
        edges_processed,
        timesteps,
        reads_skipped,
        peak_scratch_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{generate_genome, simulate_reads, ErrorProfile, XorShift};

    /// Edit-distance (banded Levenshtein) for accuracy checks.
    pub(crate) fn edit_distance(a: &[u8], b: &[u8], band: usize) -> usize {
        let n = a.len();
        let m = b.len();
        if n == 0 {
            return m;
        }
        let inf = usize::MAX / 2;
        let mut prev = vec![inf; m + 1];
        let mut cur = vec![inf; m + 1];
        for (j, p) in prev.iter_mut().enumerate().take(m + 1) {
            *p = j;
        }
        for i in 1..=n {
            cur.iter_mut().for_each(|x| *x = inf);
            let lo = i.saturating_sub(band).max(1);
            let hi = (i + band).min(m);
            if lo == 1 {
                cur[0] = i;
            }
            for j in lo..=hi {
                let cost = usize::from(a[i - 1] != b[j - 1]);
                cur[j] = (prev[j - 1] + cost).min(prev[j] + 1).min(cur[j - 1] + 1);
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        prev[m]
    }

    fn corrupt(rng: &mut XorShift, seq: &Sequence, rate: f64) -> Sequence {
        let mut data = Vec::with_capacity(seq.len());
        for &b in &seq.data {
            if rng.chance(rate) {
                match rng.below(3) {
                    0 => data.push((b + 1 + rng.below(3) as u8) % 4), // sub
                    1 => {
                        data.push(b);
                        data.push(rng.below(4) as u8); // ins
                    }
                    _ => {} // del
                }
            } else {
                data.push(b);
            }
        }
        Sequence::from_symbols("noisy_assembly", data)
    }

    #[test]
    fn end_to_end_correction_improves_assembly() {
        let mut rng = XorShift::new(99);
        let truth = generate_genome(&mut rng, 1500);
        let assembly = corrupt(&mut rng, &truth, 0.05);
        let reads = simulate_reads(
            &mut rng,
            &truth,
            12.0,
            700,
            &ErrorProfile { sub: 0.02, ins: 0.02, del: 0.02, ins_ext: 0.2 },
        );
        let read_seqs: Vec<Sequence> = reads.into_iter().map(|r| r.seq).collect();
        let cfg = CorrectionConfig { chunk_len: 300, max_iters: 2, ..Default::default() };
        let report = correct_assembly(&assembly, &read_seqs, &cfg).unwrap();

        let before = edit_distance(&assembly.data, &truth.data, 200);
        let after = edit_distance(&report.corrected.data, &truth.data, 200);
        assert!(report.chunks_trained > 0, "no chunk trained");
        assert!(
            after < before,
            "correction failed: before={before} after={after} (trained {}/{} chunks)",
            report.chunks_trained,
            report.chunks_total
        );
    }

    #[test]
    fn bw_dominates_runtime_like_fig2() {
        // Fig. 2: error correction spends ~98 % in Baum-Welch; our
        // reimplementation must be clearly BW-dominated too.
        let mut rng = XorShift::new(7);
        let truth = generate_genome(&mut rng, 1200);
        let assembly = corrupt(&mut rng, &truth, 0.03);
        let reads = simulate_reads(&mut rng, &truth, 10.0, 600, &ErrorProfile::pacbio());
        let read_seqs: Vec<Sequence> = reads.into_iter().map(|r| r.seq).collect();
        let cfg = CorrectionConfig { chunk_len: 400, ..Default::default() };
        let report = correct_assembly(&assembly, &read_seqs, &cfg).unwrap();
        assert!(
            report.timings.bw_fraction() > 0.6,
            "bw fraction {}",
            report.timings.bw_fraction()
        );
    }

    #[test]
    fn estep_workers_do_not_change_output() {
        // Per-chunk E-step threading uses the deterministic block
        // reduction: the corrected assembly must be byte-identical.
        let mut rng = XorShift::new(10);
        let truth = generate_genome(&mut rng, 900);
        let assembly = corrupt(&mut rng, &truth, 0.03);
        let reads = simulate_reads(&mut rng, &truth, 8.0, 450, &ErrorProfile::pacbio());
        let read_seqs: Vec<Sequence> = reads.into_iter().map(|r| r.seq).collect();
        let base = CorrectionConfig { chunk_len: 300, ..Default::default() };
        let one = correct_assembly(&assembly, &read_seqs, &base).unwrap();
        let four = correct_assembly(
            &assembly,
            &read_seqs,
            &CorrectionConfig { estep_workers: 4, ..base },
        )
        .unwrap();
        assert_eq!(one.corrected.data, four.corrected.data);
        assert_eq!(one.reads_skipped, four.reads_skipped);
    }

    #[test]
    fn engine_selection_is_configuration() {
        // Swapping the Baum-Welch backend is pure configuration: the
        // banded engine runs the same pipeline end-to-end and must not
        // make the assembly worse.
        let mut rng = XorShift::new(12);
        let truth = generate_genome(&mut rng, 600);
        let assembly = corrupt(&mut rng, &truth, 0.03);
        let reads = simulate_reads(&mut rng, &truth, 8.0, 300, &ErrorProfile::pacbio());
        let read_seqs: Vec<Sequence> = reads.into_iter().map(|r| r.seq).collect();
        let cfg = CorrectionConfig {
            chunk_len: 300,
            engine: EngineKind::Banded,
            ..Default::default()
        };
        let report = correct_assembly(&assembly, &read_seqs, &cfg).unwrap();
        assert!(report.chunks_trained > 0, "no chunk trained under the banded engine");
        let before = edit_distance(&assembly.data, &truth.data, 200);
        let after = edit_distance(&report.corrected.data, &truth.data, 200);
        assert!(after <= before, "banded correction regressed: {before} -> {after}");
    }

    #[test]
    fn uncovered_chunks_pass_through() {
        let mut rng = XorShift::new(8);
        let assembly = generate_genome(&mut rng, 900);
        let report = correct_assembly(&assembly, &[], &Default::default()).unwrap();
        assert_eq!(report.chunks_trained, 0);
        assert_eq!(report.corrected.data, assembly.data);
    }

    #[test]
    fn workload_counters_populated() {
        let mut rng = XorShift::new(9);
        let truth = generate_genome(&mut rng, 800);
        let reads = simulate_reads(&mut rng, &truth, 8.0, 400, &ErrorProfile::pacbio());
        let read_seqs: Vec<Sequence> = reads.into_iter().map(|r| r.seq).collect();
        let cfg = CorrectionConfig { chunk_len: 400, ..Default::default() };
        let report = correct_assembly(&truth, &read_seqs, &cfg).unwrap();
        assert!(report.states_processed > 0);
        assert!(report.edges_processed > report.states_processed);
        assert!(report.timesteps > 0);
    }

    #[test]
    fn edit_distance_sanity() {
        assert_eq!(edit_distance(b"ACGT", b"ACGT", 8), 0);
        assert_eq!(edit_distance(b"ACGT", b"AGGT", 8), 1);
        assert_eq!(edit_distance(b"ACGT", b"ACT", 8), 1);
        assert_eq!(edit_distance(b"", b"ACT", 8), 3);
    }
}
