//! Use case 3 — multiple sequence alignment (hmmalign, §2.3 / §5.6).
//!
//! Each sequence is aligned to a single family profile by posterior
//! decoding: the Forward and Backward passes produce per-timestep state
//! posteriors γ_t(i) = F̂_t(i)·B̂_t(i); every residue is assigned to its
//! maximum-posterior state, and match-state assignments define the MSA
//! columns (insertion-state residues sit between columns), which is how
//! hmmalign constructs its alignment.
//!
//! All compute routes through the [`ExpectationEngine`] selected by
//! [`MsaConfig::engine`] (default: the banded engine, whose fused
//! coefficient tables are built once per profile): the optional
//! score-only pre-screen uses [`ExpectationEngine::score`] and the
//! decode uses [`ExpectationEngine::posterior`].

use std::time::Instant;

use crate::baumwelch::{
    BandedEngine, EngineKind, ExpectationEngine, ForwardOptions, ReferenceEngine, SparseEngine,
};
use crate::error::{ApHmmError, Result};
use crate::phmm::{Phmm, StateKind};
use crate::seq::Sequence;

use super::timing::AppTimings;

/// Thresholds above this activate the score-only pre-screen: junk is
/// rejected by the engine's forward score *before* the full posterior
/// decode is paid for it.
const PRESCREEN_ACTIVE: f64 = -1e8;

/// MSA configuration.
#[derive(Clone, Copy, Debug)]
pub struct MsaConfig {
    /// Skip sequences whose length-normalized log-likelihood falls below
    /// this (junk rejection).  The default (-1e9) accepts everything;
    /// any threshold above -1e8 is additionally enforced by a cheap
    /// score-only pre-screen ahead of posterior decoding.
    pub min_avg_loglik: f64,
    /// Baum-Welch backend.  The banded engine is the natural fit
    /// (posterior decode needs dense forward rows); the sparse and
    /// reference engines fall back to a per-sequence banded lowering
    /// for the decode.
    pub engine: EngineKind,
}

impl Default for MsaConfig {
    fn default() -> Self {
        MsaConfig { min_avg_loglik: -1e9, engine: EngineKind::Banded }
    }
}

/// One aligned sequence.
#[derive(Clone, Debug)]
pub struct AlignedRow {
    /// Sequence identifier.
    pub id: String,
    /// Per-profile-column residue (None = gap).
    pub columns: Vec<Option<u8>>,
    /// Residues assigned to insertion states (not in columns).
    pub insertions: usize,
    /// Log-likelihood of the sequence under the profile.
    pub loglik: f64,
}

/// MSA run output.
#[derive(Clone, Debug)]
pub struct MsaReport {
    /// Aligned rows (skipped sequences omitted).
    pub rows: Vec<AlignedRow>,
    /// Number of profile columns.
    pub n_columns: usize,
    /// Sequences rejected by the score threshold or numeric failure.
    pub skipped: usize,
    /// Timings (Fig. 2: forward+backward vs overheads).
    pub timings: AppTimings,
}

/// Number of profile columns of an (emitting-only) profile pHMM: the
/// highest match-state position + 1.  Shared by [`align_all_with`] and
/// the serving layer's `Align` responses.
pub fn profile_columns(phmm: &Phmm) -> usize {
    phmm.kinds
        .iter()
        .zip(phmm.position.iter())
        .filter(|(k, _)| matches!(k, StateKind::Match))
        .map(|(_, &p)| p as usize + 1)
        .max()
        .unwrap_or(0)
}

/// Map a maximum-posterior state path onto profile columns (hmmalign's
/// rule): match-state residues fill their column, everything else
/// counts as an insertion.  Returns the column row plus the insertion
/// count.
pub fn posterior_columns(
    phmm: &Phmm,
    n_columns: usize,
    seq: &Sequence,
    best_state: &[u32],
) -> (Vec<Option<u8>>, usize) {
    let mut columns: Vec<Option<u8>> = vec![None; n_columns];
    let mut insertions = 0usize;
    for (t, &s) in best_state.iter().enumerate() {
        let s = s as usize;
        match phmm.kinds[s] {
            StateKind::Match => {
                let col = phmm.position[s] as usize;
                if col < n_columns && columns[col].is_none() {
                    columns[col] = Some(seq.data[t]);
                } else {
                    insertions += 1;
                }
            }
            StateKind::Insertion => insertions += 1,
            StateKind::Deletion => {}
        }
    }
    (columns, insertions)
}

/// Align all `seqs` against the (emitting-only) profile `phmm`, using
/// the engine named by `cfg.engine`.
pub fn align_all(phmm: &Phmm, seqs: &[Sequence], cfg: &MsaConfig) -> Result<MsaReport> {
    match cfg.engine {
        EngineKind::Sparse => align_all_with(&SparseEngine, phmm, seqs, cfg),
        EngineKind::Banded => align_all_with(&BandedEngine, phmm, seqs, cfg),
        EngineKind::Reference => align_all_with(&ReferenceEngine, phmm, seqs, cfg),
        EngineKind::Xla => Err(ApHmmError::Config(
            "the XLA engine is device-backed; MSA supports the in-process engines \
             (sparse | banded | reference)"
                .into(),
        )),
    }
}

/// [`align_all`] over any [`ExpectationEngine`] instance.
pub fn align_all_with<E: ExpectationEngine>(
    engine: &E,
    phmm: &Phmm,
    seqs: &[Sequence],
    cfg: &MsaConfig,
) -> Result<MsaReport> {
    let mut timings = AppTimings::default();
    // Freeze the profile once: the engine's coefficient tables are
    // shared across every sequence (non-BW time).
    let t0 = Instant::now();
    let prep = engine.prepare(phmm)?;
    let mut scratch = engine.make_scratch(phmm);
    let n_columns = profile_columns(phmm);
    timings.other_ns += t0.elapsed().as_nanos();

    let prescreen = cfg.min_avg_loglik > PRESCREEN_ACTIVE;
    let opts = ForwardOptions::default();

    let mut rows = Vec::with_capacity(seqs.len());
    let mut skipped = 0usize;
    for seq in seqs {
        if seq.is_empty() {
            skipped += 1;
            continue;
        }
        if prescreen {
            let t = Instant::now();
            let verdict = engine.score(phmm, &prep, seq, &opts, &mut scratch);
            timings.forward_ns += t.elapsed().as_nanos();
            match verdict {
                Ok(score) if score.loglik / seq.len() as f64 >= cfg.min_avg_loglik => {}
                _ => {
                    skipped += 1;
                    continue;
                }
            }
        }
        match engine.posterior(phmm, &prep, seq) {
            Ok(dec) => {
                timings.forward_ns += dec.forward_ns;
                timings.backward_update_ns += dec.backward_ns;
                if dec.loglik / seq.len() as f64 >= cfg.min_avg_loglik {
                    let t2 = Instant::now();
                    let (columns, insertions) =
                        posterior_columns(phmm, n_columns, seq, &dec.best_state);
                    rows.push(AlignedRow {
                        id: seq.id.clone(),
                        columns,
                        insertions,
                        loglik: dec.loglik,
                    });
                    timings.other_ns += t2.elapsed().as_nanos();
                } else {
                    skipped += 1;
                }
            }
            Err(_) => skipped += 1,
        }
    }
    Ok(MsaReport { rows, n_columns, skipped, timings })
}

/// Mean pairwise column identity of an alignment (quality metric).
pub fn msa_identity(report: &MsaReport) -> f64 {
    if report.rows.len() < 2 || report.n_columns == 0 {
        return 0.0;
    }
    let mut same = 0usize;
    let mut total = 0usize;
    for c in 0..report.n_columns {
        for i in 0..report.rows.len() {
            for j in i + 1..report.rows.len() {
                if let (Some(a), Some(b)) = (report.rows[i].columns[c], report.rows[j].columns[c])
                {
                    total += 1;
                    if a == b {
                        same += 1;
                    }
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::{Profile, TraditionalParams};
    use crate::seq::PROTEIN;
    use crate::sim::{generate_families, ProteinSimParams, XorShift};

    fn family_profile(
        rng: &mut XorShift,
    ) -> (crate::sim::ProteinFamily, Phmm) {
        let fams = generate_families(
            rng,
            &ProteinSimParams { n_families: 1, members_per_family: 10, ..Default::default() },
        );
        let fam = fams.into_iter().next().unwrap();
        let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
        let phmm = Phmm::traditional(&profile, &TraditionalParams::default())
            .unwrap()
            .fold_silent(4)
            .unwrap();
        (fam, phmm)
    }

    #[test]
    fn family_members_align_with_high_identity() {
        let mut rng = XorShift::new(21);
        let (fam, phmm) = family_profile(&mut rng);
        let report = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
        assert_eq!(report.rows.len(), fam.members.len());
        let id = msa_identity(&report);
        // Members diverge ~15 % from the ancestor; aligned identity must
        // be far above the 1/20 random baseline.
        assert!(id > 0.5, "identity {id}");
    }

    #[test]
    fn alignment_covers_most_columns() {
        let mut rng = XorShift::new(22);
        let (fam, phmm) = family_profile(&mut rng);
        let report = align_all(&phmm, &fam.members[..3], &MsaConfig::default()).unwrap();
        for row in &report.rows {
            let filled = row.columns.iter().filter(|c| c.is_some()).count();
            assert!(
                filled as f64 > report.n_columns as f64 * 0.6,
                "row {} fills {filled}/{}",
                row.id,
                report.n_columns
            );
        }
    }

    #[test]
    fn timings_are_bw_dominated() {
        let mut rng = XorShift::new(23);
        let (fam, phmm) = family_profile(&mut rng);
        let report = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
        assert!(report.timings.bw_fraction() > 0.4, "{}", report.timings.bw_fraction());
    }

    #[test]
    fn engines_produce_identical_alignments() {
        // The posterior decode is the same banded computation whichever
        // engine fronts it, so the alignments must agree exactly.
        let mut rng = XorShift::new(26);
        let (fam, phmm) = family_profile(&mut rng);
        let seqs = &fam.members[..4];
        let banded = align_all(
            &phmm,
            seqs,
            &MsaConfig { engine: EngineKind::Banded, ..Default::default() },
        )
        .unwrap();
        let sparse = align_all(
            &phmm,
            seqs,
            &MsaConfig { engine: EngineKind::Sparse, ..Default::default() },
        )
        .unwrap();
        assert_eq!(banded.rows.len(), sparse.rows.len());
        for (a, b) in banded.rows.iter().zip(sparse.rows.iter()) {
            assert_eq!(a.columns, b.columns, "row {}", a.id);
            assert_eq!(a.insertions, b.insertions, "row {}", a.id);
        }
    }

    #[test]
    fn xla_engine_is_rejected_for_msa() {
        let mut rng = XorShift::new(27);
        let (fam, phmm) = family_profile(&mut rng);
        let cfg = MsaConfig { engine: EngineKind::Xla, ..Default::default() };
        assert!(align_all(&phmm, &fam.members[..1], &cfg).is_err());
    }

    #[test]
    fn prescreen_rejects_junk_before_posterior_decode() {
        use crate::sim::XorShift as Rng;
        let mut rng = Rng::new(25);
        let (fam, phmm) = family_profile(&mut rng);
        // Random residues score far below real members per residue.
        let junk = Sequence::from_symbols(
            "junk",
            crate::testutil::random_seq(&mut rng, 80, 20),
        );
        let mut seqs = fam.members[..4].to_vec();
        seqs.push(junk.clone());
        // Pick a threshold strictly between the worst member and the
        // junk (machine-independent: derived from the scores themselves).
        let avg = |s: &Sequence| {
            crate::baumwelch::score_sparse(&phmm, s, &ForwardOptions::default()).unwrap()
                / s.len() as f64
        };
        let mut worst_member = f64::INFINITY;
        for s in &seqs[..4] {
            worst_member = worst_member.min(avg(s));
        }
        let junk_score = avg(&junk);
        assert!(
            worst_member > junk_score,
            "profile cannot separate members ({worst_member}) from junk ({junk_score})"
        );
        let cfg = MsaConfig {
            min_avg_loglik: (worst_member + junk_score) / 2.0,
            ..Default::default()
        };
        let report = align_all(&phmm, &seqs, &cfg).unwrap();
        assert_eq!(report.rows.len(), 4, "members must survive the pre-screen");
        assert_eq!(report.skipped, 1, "junk must be rejected");
        assert!(report.rows.iter().all(|r| r.id != "junk"));
    }

    #[test]
    fn empty_sequences_are_skipped() {
        let mut rng = XorShift::new(24);
        let (fam, phmm) = family_profile(&mut rng);
        let mut seqs = fam.members.clone();
        seqs.push(Sequence::from_symbols("empty", vec![]));
        let report = align_all(&phmm, &seqs, &MsaConfig::default()).unwrap();
        assert_eq!(report.skipped, 1);
    }
}
