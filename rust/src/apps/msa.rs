//! Use case 3 — multiple sequence alignment (hmmalign, §2.3 / §5.6).
//!
//! Each sequence is aligned to a single family profile by posterior
//! decoding: the Forward and Backward passes produce per-timestep state
//! posteriors γ_t(i) = F̂_t(i)·B̂_t(i); every residue is assigned to its
//! maximum-posterior state, and match-state assignments define the MSA
//! columns (insertion-state residues sit between columns), which is how
//! hmmalign constructs its alignment.
//!
//! All compute routes through the [`ExpectationEngine`] selected by
//! [`MsaConfig::engine`] (default: the banded engine, whose fused
//! coefficient tables are built once per profile): the optional
//! score-only pre-screen uses [`ExpectationEngine::score`] and the
//! decode uses [`ExpectationEngine::posterior`].

use std::time::Instant;

use crate::baumwelch::{
    train_source_with_engine_with, BandedEngine, EngineKind, ExpectationEngine, ForwardOptions,
    ReadSource, ReferenceEngine, SparseEngine, TrainConfig, TrainMode, TrainResult,
};
use crate::cancel::CancelToken;
use crate::error::{ApHmmError, Result};
use crate::phmm::{Phmm, StateKind};
use crate::pool::WorkerPool;
use crate::seq::Sequence;

use super::timing::AppTimings;

/// Thresholds above this activate the score-only pre-screen: junk is
/// rejected by the engine's forward score *before* the full posterior
/// decode is paid for it.
const PRESCREEN_ACTIVE: f64 = -1e8;

/// Reads resident at once during a streamed alignment pass
/// ([`align_all_streamed`]): decode proceeds window by window, so the
/// corpus size never bounds memory — only this constant does.
const ALIGN_WINDOW: usize = 512;

/// MSA configuration.
#[derive(Clone, Copy, Debug)]
pub struct MsaConfig {
    /// Skip sequences whose length-normalized log-likelihood falls below
    /// this (junk rejection).  The default (-1e9) accepts everything;
    /// any threshold above -1e8 is additionally enforced by a cheap
    /// score-only pre-screen ahead of posterior decoding.
    pub min_avg_loglik: f64,
    /// Baum-Welch backend.  The banded engine is the natural fit
    /// (posterior decode needs dense forward rows); the sparse and
    /// reference engines fall back to a per-sequence banded lowering
    /// for the decode.
    pub engine: EngineKind,
    /// Profile-training epochs run before a streamed alignment
    /// ([`align_all_streamed`]); `0` aligns against the profile as
    /// given.  Ignored by the slice-based [`align_all`], whose profile
    /// is immutable.
    pub train_iters: usize,
    /// Training schedule of that pass.  The [`TrainMode::Auto`] default
    /// picks minibatch for streaming/large corpora — the learnMSA
    /// recipe for million-sequence alignment — and full batch for small
    /// in-memory ones.
    pub mode: TrainMode,
    /// Shuffle seed of the minibatch schedule.
    pub seed: u64,
}

impl Default for MsaConfig {
    fn default() -> Self {
        MsaConfig {
            min_avg_loglik: -1e9,
            engine: EngineKind::Banded,
            train_iters: 0,
            mode: TrainMode::Auto,
            seed: 1,
        }
    }
}

/// One aligned sequence.
#[derive(Clone, Debug)]
pub struct AlignedRow {
    /// Sequence identifier.
    pub id: String,
    /// Per-profile-column residue (None = gap).
    pub columns: Vec<Option<u8>>,
    /// Residues assigned to insertion states (not in columns).
    pub insertions: usize,
    /// Log-likelihood of the sequence under the profile.
    pub loglik: f64,
}

/// MSA run output.
#[derive(Clone, Debug, Default)]
pub struct MsaReport {
    /// Aligned rows (skipped sequences omitted).
    pub rows: Vec<AlignedRow>,
    /// Number of profile columns.
    pub n_columns: usize,
    /// Sequences rejected by the score threshold or numeric failure.
    pub skipped: usize,
    /// Timings (Fig. 2: forward+backward vs overheads).
    pub timings: AppTimings,
    /// Training outcome of the pre-alignment pass
    /// ([`align_all_streamed`] with `train_iters > 0`); `None` when the
    /// profile was used as given.
    pub train: Option<TrainResult>,
    /// Sequences pulled through the streaming source during the decode
    /// pass (0 for the slice-based path).
    pub sequences_streamed: u64,
}

/// Number of profile columns of an (emitting-only) profile pHMM: the
/// highest match-state position + 1.  Shared by [`align_all_with`] and
/// the serving layer's `Align` responses.
pub fn profile_columns(phmm: &Phmm) -> usize {
    phmm.kinds
        .iter()
        .zip(phmm.position.iter())
        .filter(|(k, _)| matches!(k, StateKind::Match))
        .map(|(_, &p)| p as usize + 1)
        .max()
        .unwrap_or(0)
}

/// Map a maximum-posterior state path onto profile columns (hmmalign's
/// rule): match-state residues fill their column, everything else
/// counts as an insertion.  Returns the column row plus the insertion
/// count.
pub fn posterior_columns(
    phmm: &Phmm,
    n_columns: usize,
    seq: &Sequence,
    best_state: &[u32],
) -> (Vec<Option<u8>>, usize) {
    let mut columns: Vec<Option<u8>> = vec![None; n_columns];
    let mut insertions = 0usize;
    for (t, &s) in best_state.iter().enumerate() {
        let s = s as usize;
        match phmm.kinds[s] {
            StateKind::Match => {
                let col = phmm.position[s] as usize;
                if col < n_columns && columns[col].is_none() {
                    columns[col] = Some(seq.data[t]);
                } else {
                    insertions += 1;
                }
            }
            StateKind::Insertion => insertions += 1,
            StateKind::Deletion => {}
        }
    }
    (columns, insertions)
}

/// Align all `seqs` against the (emitting-only) profile `phmm`, using
/// the engine named by `cfg.engine`.
pub fn align_all(phmm: &Phmm, seqs: &[Sequence], cfg: &MsaConfig) -> Result<MsaReport> {
    match cfg.engine {
        EngineKind::Sparse => align_all_with(&SparseEngine, phmm, seqs, cfg),
        EngineKind::Banded => align_all_with(&BandedEngine, phmm, seqs, cfg),
        EngineKind::Reference => align_all_with(&ReferenceEngine, phmm, seqs, cfg),
        EngineKind::Xla => Err(ApHmmError::Config(
            "the XLA engine is device-backed; MSA supports the in-process engines \
             (sparse | banded | reference)"
                .into(),
        )),
    }
}

/// Decode one window of sequences against a frozen profile, appending
/// rows/skips/timings into `report` — the per-sequence core shared by
/// the slice and streamed paths.
fn align_window_with<E: ExpectationEngine>(
    engine: &E,
    phmm: &Phmm,
    prep: &E::Prepared,
    scratch: &mut E::Scratch,
    seqs: &[Sequence],
    cfg: &MsaConfig,
    report: &mut MsaReport,
) {
    let prescreen = cfg.min_avg_loglik > PRESCREEN_ACTIVE;
    let opts = ForwardOptions::default();
    for seq in seqs {
        if seq.is_empty() {
            report.skipped += 1;
            continue;
        }
        if prescreen {
            let t = Instant::now();
            let verdict = engine.score(phmm, prep, seq, &opts, scratch);
            report.timings.forward_ns += t.elapsed().as_nanos();
            match verdict {
                Ok(score) if score.loglik / seq.len() as f64 >= cfg.min_avg_loglik => {}
                _ => {
                    report.skipped += 1;
                    continue;
                }
            }
        }
        match engine.posterior(phmm, prep, seq) {
            Ok(dec) => {
                report.timings.forward_ns += dec.forward_ns;
                report.timings.backward_update_ns += dec.backward_ns;
                if dec.loglik / seq.len() as f64 >= cfg.min_avg_loglik {
                    let t2 = Instant::now();
                    let (columns, insertions) =
                        posterior_columns(phmm, report.n_columns, seq, &dec.best_state);
                    report.rows.push(AlignedRow {
                        id: seq.id.clone(),
                        columns,
                        insertions,
                        loglik: dec.loglik,
                    });
                    report.timings.other_ns += t2.elapsed().as_nanos();
                } else {
                    report.skipped += 1;
                }
            }
            Err(_) => report.skipped += 1,
        }
    }
}

/// [`align_all`] over any [`ExpectationEngine`] instance.
pub fn align_all_with<E: ExpectationEngine>(
    engine: &E,
    phmm: &Phmm,
    seqs: &[Sequence],
    cfg: &MsaConfig,
) -> Result<MsaReport> {
    let mut report = MsaReport::default();
    // Freeze the profile once: the engine's coefficient tables are
    // shared across every sequence (non-BW time).
    let t0 = Instant::now();
    let prep = engine.prepare(phmm)?;
    let mut scratch = engine.make_scratch(phmm);
    report.n_columns = profile_columns(phmm);
    report.timings.other_ns += t0.elapsed().as_nanos();
    align_window_with(engine, phmm, &prep, &mut scratch, seqs, cfg, &mut report);
    Ok(report)
}

/// Streamed MSA: optionally train the profile on the corpus (minibatch
/// by default for streaming sources — the learnMSA recipe), then
/// posterior-decode it window by window.
///
/// Unlike [`align_all`], which needs every sequence resident, this
/// holds at most [`ALIGN_WINDOW`] sequences during the decode pass (and
/// the trainer's shuffle window during training), so million-sequence
/// FASTA files align in bounded memory.  Alignment *rows* still
/// accumulate in the report — callers that also want bounded output
/// should consume `report.rows` per window; the memory bound documented
/// in `baumwelch/README.md` § Memory modes covers the sequence
/// residency this function controls.
pub fn align_all_streamed(
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &MsaConfig,
) -> Result<MsaReport> {
    match cfg.engine {
        EngineKind::Sparse => align_all_streamed_with(&SparseEngine, phmm, source, cfg),
        EngineKind::Banded => align_all_streamed_with(&BandedEngine, phmm, source, cfg),
        EngineKind::Reference => align_all_streamed_with(&ReferenceEngine, phmm, source, cfg),
        EngineKind::Xla => Err(ApHmmError::Config(
            "the XLA engine is device-backed; MSA supports the in-process engines \
             (sparse | banded | reference)"
                .into(),
        )),
    }
}

/// [`align_all_streamed`] over any [`ExpectationEngine`] instance.
pub fn align_all_streamed_with<E: ExpectationEngine>(
    engine: &E,
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &MsaConfig,
) -> Result<MsaReport> {
    let mut report = MsaReport::default();
    if cfg.train_iters > 0 {
        let tcfg = TrainConfig {
            max_iters: cfg.train_iters,
            tol: 0.0,
            mode: cfg.mode,
            seed: cfg.seed,
            ..Default::default()
        };
        let train = train_source_with_engine_with(
            engine,
            phmm,
            source,
            &tcfg,
            WorkerPool::global(),
            &CancelToken::none(),
        )?;
        report.timings.forward_ns += train.forward_ns;
        report.timings.backward_update_ns += train.backward_update_ns;
        report.timings.maximize_ns += train.maximize_ns;
        report.sequences_streamed += train.sequences_streamed;
        report.train = Some(train);
    }
    // Freeze the (possibly refined) profile once, then decode in
    // bounded windows.
    let t0 = Instant::now();
    let prep = engine.prepare(phmm)?;
    let mut scratch = engine.make_scratch(phmm);
    report.n_columns = profile_columns(phmm);
    report.timings.other_ns += t0.elapsed().as_nanos();
    source.reset()?;
    let mut window: Vec<Sequence> = Vec::with_capacity(ALIGN_WINDOW);
    loop {
        if source.fill(ALIGN_WINDOW, &mut window)? == 0 {
            break;
        }
        report.sequences_streamed += window.len() as u64;
        align_window_with(engine, phmm, &prep, &mut scratch, &window, cfg, &mut report);
        window.clear();
    }
    Ok(report)
}

/// Mean pairwise column identity of an alignment (quality metric).
pub fn msa_identity(report: &MsaReport) -> f64 {
    if report.rows.len() < 2 || report.n_columns == 0 {
        return 0.0;
    }
    let mut same = 0usize;
    let mut total = 0usize;
    for c in 0..report.n_columns {
        for i in 0..report.rows.len() {
            for j in i + 1..report.rows.len() {
                if let (Some(a), Some(b)) = (report.rows[i].columns[c], report.rows[j].columns[c])
                {
                    total += 1;
                    if a == b {
                        same += 1;
                    }
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::{Profile, TraditionalParams};
    use crate::seq::PROTEIN;
    use crate::sim::{generate_families, ProteinSimParams, XorShift};

    fn family_profile(
        rng: &mut XorShift,
    ) -> (crate::sim::ProteinFamily, Phmm) {
        let fams = generate_families(
            rng,
            &ProteinSimParams { n_families: 1, members_per_family: 10, ..Default::default() },
        );
        let fam = fams.into_iter().next().unwrap();
        let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
        let phmm = Phmm::traditional(&profile, &TraditionalParams::default())
            .unwrap()
            .fold_silent(4)
            .unwrap();
        (fam, phmm)
    }

    #[test]
    fn streamed_alignment_matches_slice_alignment() {
        let mut rng = XorShift::new(23);
        let (fam, phmm) = family_profile(&mut rng);
        let cfg = MsaConfig::default();
        let slice = align_all(&phmm, &fam.members, &cfg).unwrap();
        let mut src = crate::baumwelch::MemorySource::new(&fam.members);
        let mut phmm2 = phmm.clone();
        let streamed = align_all_streamed(&mut phmm2, &mut src, &cfg).unwrap();
        assert_eq!(streamed.rows.len(), slice.rows.len());
        assert_eq!(streamed.skipped, slice.skipped);
        assert_eq!(streamed.n_columns, slice.n_columns);
        assert_eq!(streamed.sequences_streamed, fam.members.len() as u64);
        assert!(streamed.train.is_none());
        for (a, b) in streamed.rows.iter().zip(&slice.rows) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.columns, b.columns);
            assert_eq!(a.loglik, b.loglik, "decode must be bit-identical");
        }
    }

    #[test]
    fn streamed_alignment_can_train_first() {
        let mut rng = XorShift::new(24);
        let (fam, phmm) = family_profile(&mut rng);
        let cfg = MsaConfig { train_iters: 2, mode: TrainMode::Minibatch, ..Default::default() };
        let mut src = crate::baumwelch::MemorySource::new(&fam.members);
        let mut phmm2 = phmm.clone();
        let report = align_all_streamed(&mut phmm2, &mut src, &cfg).unwrap();
        let train = report.train.expect("training pass must be reported");
        assert!(train.iters >= 1);
        assert!(train.minibatches >= 1);
        assert_eq!(report.rows.len(), fam.members.len());
        // Decode streamed the corpus once more after the training pass.
        assert!(report.sequences_streamed >= train.sequences_streamed + fam.members.len() as u64);
        let id = msa_identity(&report);
        assert!(id > 0.5, "identity {id} after refinement");
    }

    #[test]
    fn family_members_align_with_high_identity() {
        let mut rng = XorShift::new(21);
        let (fam, phmm) = family_profile(&mut rng);
        let report = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
        assert_eq!(report.rows.len(), fam.members.len());
        let id = msa_identity(&report);
        // Members diverge ~15 % from the ancestor; aligned identity must
        // be far above the 1/20 random baseline.
        assert!(id > 0.5, "identity {id}");
    }

    #[test]
    fn alignment_covers_most_columns() {
        let mut rng = XorShift::new(22);
        let (fam, phmm) = family_profile(&mut rng);
        let report = align_all(&phmm, &fam.members[..3], &MsaConfig::default()).unwrap();
        for row in &report.rows {
            let filled = row.columns.iter().filter(|c| c.is_some()).count();
            assert!(
                filled as f64 > report.n_columns as f64 * 0.6,
                "row {} fills {filled}/{}",
                row.id,
                report.n_columns
            );
        }
    }

    #[test]
    fn timings_are_bw_dominated() {
        let mut rng = XorShift::new(23);
        let (fam, phmm) = family_profile(&mut rng);
        let report = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
        assert!(report.timings.bw_fraction() > 0.4, "{}", report.timings.bw_fraction());
    }

    #[test]
    fn engines_produce_identical_alignments() {
        // The posterior decode is the same banded computation whichever
        // engine fronts it, so the alignments must agree exactly.
        let mut rng = XorShift::new(26);
        let (fam, phmm) = family_profile(&mut rng);
        let seqs = &fam.members[..4];
        let banded = align_all(
            &phmm,
            seqs,
            &MsaConfig { engine: EngineKind::Banded, ..Default::default() },
        )
        .unwrap();
        let sparse = align_all(
            &phmm,
            seqs,
            &MsaConfig { engine: EngineKind::Sparse, ..Default::default() },
        )
        .unwrap();
        assert_eq!(banded.rows.len(), sparse.rows.len());
        for (a, b) in banded.rows.iter().zip(sparse.rows.iter()) {
            assert_eq!(a.columns, b.columns, "row {}", a.id);
            assert_eq!(a.insertions, b.insertions, "row {}", a.id);
        }
    }

    #[test]
    fn xla_engine_is_rejected_for_msa() {
        let mut rng = XorShift::new(27);
        let (fam, phmm) = family_profile(&mut rng);
        let cfg = MsaConfig { engine: EngineKind::Xla, ..Default::default() };
        assert!(align_all(&phmm, &fam.members[..1], &cfg).is_err());
    }

    #[test]
    fn prescreen_rejects_junk_before_posterior_decode() {
        use crate::sim::XorShift as Rng;
        let mut rng = Rng::new(25);
        let (fam, phmm) = family_profile(&mut rng);
        // Random residues score far below real members per residue.
        let junk = Sequence::from_symbols(
            "junk",
            crate::testutil::random_seq(&mut rng, 80, 20),
        );
        let mut seqs = fam.members[..4].to_vec();
        seqs.push(junk.clone());
        // Pick a threshold strictly between the worst member and the
        // junk (machine-independent: derived from the scores themselves).
        let avg = |s: &Sequence| {
            crate::baumwelch::score_sparse(&phmm, s, &ForwardOptions::default()).unwrap()
                / s.len() as f64
        };
        let mut worst_member = f64::INFINITY;
        for s in &seqs[..4] {
            worst_member = worst_member.min(avg(s));
        }
        let junk_score = avg(&junk);
        assert!(
            worst_member > junk_score,
            "profile cannot separate members ({worst_member}) from junk ({junk_score})"
        );
        let cfg = MsaConfig {
            min_avg_loglik: (worst_member + junk_score) / 2.0,
            ..Default::default()
        };
        let report = align_all(&phmm, &seqs, &cfg).unwrap();
        assert_eq!(report.rows.len(), 4, "members must survive the pre-screen");
        assert_eq!(report.skipped, 1, "junk must be rejected");
        assert!(report.rows.iter().all(|r| r.id != "junk"));
    }

    #[test]
    fn empty_sequences_are_skipped() {
        let mut rng = XorShift::new(24);
        let (fam, phmm) = family_profile(&mut rng);
        let mut seqs = fam.members.clone();
        seqs.push(Sequence::from_symbols("empty", vec![]));
        let report = align_all(&phmm, &seqs, &MsaConfig::default()).unwrap();
        assert_eq!(report.skipped, 1);
    }
}
