//! Deterministic fault injection (`failpoint!`), cfg-gated.
//!
//! Fault-tolerance code is only trustworthy if every failure path is
//! exercised by a *deterministic* test — timing luck (sleeping and
//! hoping a deadline fires mid-compute) is not a test.  This module
//! provides a tiny registry of named failure sites; production code
//! marks the sites with the [`failpoint!`] macro and tests arm them
//! with [`configure`]/[`configure_times`].
//!
//! The whole facility is gated behind the `failpoints` cargo feature:
//! without it the macro expands to nothing (an empty block), so the
//! hot paths carry zero cost and `cargo build` proves the sites
//! compile away.  Named sites currently wired in:
//!
//! | site                 | location                                   |
//! |----------------------|--------------------------------------------|
//! | `queue::pop`         | `TenantQueue::pop`, after an item is taken |
//! | `cache::insert`      | `PreparedCache::get_or_freeze`, miss path  |
//! | `engine::accumulate` | `baumwelch::train::process_block`, per read|
//! | `wire::io`           | `session::serve_connection`, per line      |
//!
//! Tests that arm failpoints must hold a [`scenario`] guard: the
//! registry is process-global and the test harness runs tests
//! concurrently, so the guard serializes failpoint scenarios and
//! clears the registry on entry and exit.

#![cfg(feature = "failpoints")]

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// What an armed failpoint does when execution reaches it.
#[derive(Clone, Debug)]
pub enum Action {
    /// Panic with the given message (exercises panic containment).
    Panic(String),
    /// Sleep for the given number of milliseconds (holds a job inside
    /// a compute loop so deadlines/cancellation can fire mid-flight).
    Sleep(u64),
    /// Yield an error message; the site maps it into a typed error and
    /// returns it (exercises error paths like a failed cache insert).
    Error(String),
}

struct Entry {
    action: Action,
    /// `Some(n)`: fire `n` more times, then disarm. `None`: always.
    remaining: Option<u64>,
}

fn registry() -> &'static Mutex<HashMap<String, Entry>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, Entry>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Arm `name` with `action` until cleared.
pub fn configure(name: &str, action: Action) {
    registry().lock().unwrap().insert(name.to_string(), Entry { action, remaining: None });
}

/// Arm `name` with `action` for exactly `times` firings, then disarm.
pub fn configure_times(name: &str, action: Action, times: u64) {
    registry()
        .lock()
        .unwrap()
        .insert(name.to_string(), Entry { action, remaining: Some(times) });
}

/// Disarm `name`.
pub fn clear(name: &str) {
    registry().lock().unwrap().remove(name);
}

/// Disarm every failpoint.
pub fn reset() {
    registry().lock().unwrap().clear();
}

/// Evaluate the failpoint `name`: perform `Panic`/`Sleep` side effects
/// inline and return `Some(message)` iff an `Error` action fired.
/// Called by the [`failpoint!`] macro, not directly.
pub fn eval(name: &str) -> Option<String> {
    let action = {
        let mut reg = registry().lock().unwrap();
        let entry = reg.get_mut(name)?;
        if let Some(n) = &mut entry.remaining {
            if *n == 0 {
                return None;
            }
            *n -= 1;
        }
        entry.action.clone()
        // Lock released here: a Sleep/Panic must not hold the registry.
    };
    match action {
        Action::Panic(msg) => panic!("failpoint {name}: {msg}"),
        Action::Sleep(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            None
        }
        Action::Error(msg) => Some(msg),
    }
}

/// Serialize failpoint scenarios across concurrently-running tests.
///
/// Holds a process-global mutex for its lifetime and clears the
/// registry both on acquisition and on drop, so a scenario can never
/// observe (or leak) another test's armed failpoints.
pub fn scenario() -> Scenario {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    let gate = GATE.get_or_init(|| Mutex::new(()));
    // A test that panicked mid-scenario poisons the gate; the lock
    // itself is still a valid serialization point.
    let guard = match gate.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    reset();
    Scenario { _guard: guard }
}

/// Guard returned by [`scenario`]; see there.
pub struct Scenario {
    _guard: MutexGuard<'static, ()>,
}

impl Drop for Scenario {
    fn drop(&mut self) {
        reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_sites_are_inert_and_times_disarms() {
        let _s = scenario();
        assert!(eval("t::nowhere").is_none());
        configure_times("t::err", Action::Error("boom".into()), 2);
        assert_eq!(eval("t::err").as_deref(), Some("boom"));
        assert_eq!(eval("t::err").as_deref(), Some("boom"));
        assert!(eval("t::err").is_none(), "failpoint must disarm after N firings");
        configure("t::err", Action::Error("again".into()));
        assert_eq!(eval("t::err").as_deref(), Some("again"));
        clear("t::err");
        assert!(eval("t::err").is_none());
    }
}
