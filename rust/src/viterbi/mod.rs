//! Viterbi decoding (§2.3): consensus-sequence inference from a trained
//! error-correction pHMM.
//!
//! Apollo's inference step: after Baum-Welch training, the most likely
//! state path through the graph is decoded and translated back into a
//! corrected sequence — match states emit their argmax character,
//! insertion states insert theirs, skipped positions are deletions.
//!
//! Decoding is over the *graph* (length-free), not an observation: we
//! search the highest-probability path from an initial state to any
//! terminal state, where each emitting state contributes its best
//! emission probability.  This is the consensus-string extraction the
//! paper attributes to Viterbi [104] as used by Apollo [43].

use crate::error::{ApHmmError, Result};
use crate::phmm::{Phmm, StateKind};
use crate::seq::Sequence;

/// A decoded consensus path.
#[derive(Clone, Debug)]
pub struct ConsensusPath {
    /// State indices along the best path.
    pub states: Vec<u32>,
    /// Log-probability of the path (transitions + best emissions).
    pub log_prob: f64,
    /// The decoded consensus sequence.
    pub consensus: Sequence,
}

#[inline]
fn ln(p: f32) -> f64 {
    if p <= 0.0 {
        f64::NEG_INFINITY
    } else {
        (p as f64).ln()
    }
}

/// Best emission (log-prob, symbol) of a state.
fn best_emission(phmm: &Phmm, i: usize) -> (f64, u8) {
    let row = phmm.emission_row(i);
    let mut best = (f64::NEG_INFINITY, 0u8);
    for (c, &p) in row.iter().enumerate() {
        let lp = ln(p);
        if lp > best.0 {
            best = (lp, c as u8);
        }
    }
    best
}

/// Decode the consensus path of a trained (emitting-only) pHMM.
///
/// Dynamic program over the DAG in topological (index) order:
/// `score[i] = best over (f_init[i], max_j score[j] + ln α_{ji}) + ln e*_i`
/// with backpointers; the best-scoring terminal state wins.  Self-loops
/// (traditional insertion states) are excluded from the max — a loop
/// can only decrease a log-probability path score, so this is exact.
pub fn consensus(phmm: &Phmm) -> Result<ConsensusPath> {
    if phmm.has_silent_states() {
        return Err(ApHmmError::InvalidGraph("consensus requires an emitting graph".into()));
    }
    let n = phmm.n_states();
    if n == 0 {
        return Err(ApHmmError::InvalidGraph("empty graph".into()));
    }
    let mut score = vec![f64::NEG_INFINITY; n];
    let mut back = vec![u32::MAX; n];
    let mut best_sym = vec![0u8; n];
    for i in 0..n {
        let (le, sym) = best_emission(phmm, i);
        best_sym[i] = sym;
        if phmm.f_init[i] > 0.0 {
            score[i] = ln(phmm.f_init[i]) + le;
        }
    }
    // Relax edges in topological (index) order.
    for j in 0..n {
        if score[j] == f64::NEG_INFINITY {
            continue;
        }
        for (to, p) in phmm.outgoing(j) {
            let to_us = to as usize;
            if to_us == j {
                continue; // self-loop: never improves a path
            }
            let (le, _) = best_emission(phmm, to_us);
            let cand = score[j] + ln(p) + le;
            if cand > score[to_us] {
                score[to_us] = cand;
                back[to_us] = j as u32;
            }
        }
    }
    // Best terminal state = state with no outgoing edges (or globally
    // best if the graph has none, which only happens in degenerate
    // tests).
    let mut best_end = usize::MAX;
    let mut best_score = f64::NEG_INFINITY;
    for i in 0..n {
        let terminal = phmm.out_ptr[i + 1] == phmm.out_ptr[i];
        if terminal && score[i] > best_score {
            best_score = score[i];
            best_end = i;
        }
    }
    if best_end == usize::MAX {
        // No terminal state reachable; fall back to the global best.
        for i in 0..n {
            if score[i] > best_score {
                best_score = score[i];
                best_end = i;
            }
        }
    }
    if best_end == usize::MAX || best_score == f64::NEG_INFINITY {
        return Err(ApHmmError::Numerical("no consensus path found".into()));
    }
    // Trace back.
    let mut states = Vec::new();
    let mut cur = best_end as u32;
    loop {
        states.push(cur);
        if back[cur as usize] == u32::MAX {
            break;
        }
        cur = back[cur as usize];
    }
    states.reverse();
    let data: Vec<u8> = states.iter().map(|&s| best_sym[s as usize]).collect();
    Ok(ConsensusPath {
        log_prob: best_score,
        consensus: Sequence::from_symbols("consensus", data),
        states,
    })
}

/// A decoded observation path (the hard E-step of Viterbi training).
#[derive(Clone, Debug)]
pub struct ViterbiPath {
    /// State index per timestep (`states.len() == read.len()`).
    pub states: Vec<u32>,
    /// `ln P(read, path | G)` of the best path.
    pub log_prob: f64,
}

/// Most likely state path of `read` through an emitting pHMM —
/// observation-dependent Viterbi in log space (unlike [`consensus`],
/// which decodes the graph alone).
///
/// The forward push mirrors the Baum-Welch forward recurrence: same
/// init states, same outgoing CSR edges, self-loops included, and the
/// path may end in any state (reads cover arbitrary windows of the
/// graph, matching the forward pass's termination).  Ties resolve to
/// the lowest-indexed predecessor, so decoding is fully deterministic.
///
/// A read with no surviving path under the current parameters — an
/// out-of-alphabet symbol, or every candidate underflowing to zero —
/// fails with [`ApHmmError::Numerical`], which the training loop counts
/// as a skipped read (the same contract as the soft E-step).
pub fn viterbi_path(phmm: &Phmm, read: &Sequence) -> Result<ViterbiPath> {
    if phmm.has_silent_states() {
        return Err(ApHmmError::InvalidGraph("viterbi_path requires an emitting graph".into()));
    }
    let n = phmm.n_states();
    if n == 0 {
        return Err(ApHmmError::InvalidGraph("empty graph".into()));
    }
    let t_len = read.len();
    if t_len == 0 {
        return Err(ApHmmError::Numerical("viterbi_path on an empty read".into()));
    }
    if read.data.iter().any(|&c| c as usize >= phmm.sigma()) {
        return Err(ApHmmError::Numerical("read contains out-of-alphabet symbols".into()));
    }
    let mut prev = vec![f64::NEG_INFINITY; n];
    let mut cur = vec![f64::NEG_INFINITY; n];
    // One backpointer row per timestep after the first.
    let mut back: Vec<Vec<u32>> = Vec::with_capacity(t_len - 1);
    for (i, f) in phmm.init_states() {
        let iu = i as usize;
        prev[iu] = ln(f) + ln(phmm.emission(iu, read.data[0]));
    }
    if prev.iter().all(|&v| v == f64::NEG_INFINITY) {
        return Err(ApHmmError::Numerical("viterbi died at t=0".into()));
    }
    for t in 1..t_len {
        let sym = read.data[t];
        cur.iter_mut().for_each(|v| *v = f64::NEG_INFINITY);
        let mut bp = vec![u32::MAX; n];
        for j in 0..n {
            let vj = prev[j];
            if vj == f64::NEG_INFINITY {
                continue;
            }
            for (to, p) in phmm.outgoing(j) {
                let tu = to as usize;
                let cand = vj + ln(p) + ln(phmm.emission(tu, sym));
                // Strict `>`: the lowest-indexed predecessor keeps ties.
                if cand > cur[tu] {
                    cur[tu] = cand;
                    bp[tu] = j as u32;
                }
            }
        }
        if cur.iter().all(|&v| v == f64::NEG_INFINITY) {
            return Err(ApHmmError::Numerical(format!("viterbi died at t={t}")));
        }
        back.push(bp);
        std::mem::swap(&mut prev, &mut cur);
    }
    let mut best_end = 0usize;
    let mut best = prev[0];
    for (i, &v) in prev.iter().enumerate().skip(1) {
        if v > best {
            best = v;
            best_end = i;
        }
    }
    let mut states = vec![0u32; t_len];
    let mut at = best_end as u32;
    states[t_len - 1] = at;
    for t in (1..t_len).rev() {
        at = back[t - 1][at as usize];
        debug_assert_ne!(at, u32::MAX, "backpointer chain broken at t={t}");
        states[t - 1] = at;
    }
    Ok(ViterbiPath { states, log_prob: best })
}

/// Count states of each kind along a path (diagnostics).
pub fn path_composition(phmm: &Phmm, path: &[u32]) -> (usize, usize) {
    let mut matches = 0;
    let mut insertions = 0;
    for &s in path {
        match phmm.kinds[s as usize] {
            StateKind::Match => matches += 1,
            StateKind::Insertion => insertions += 1,
            StateKind::Deletion => {}
        }
    }
    (matches, insertions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::{train, TrainConfig};
    use crate::phmm::EcDesignParams;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    #[test]
    fn untrained_graph_decodes_reference() {
        // With peaked match emissions and dominant match transitions the
        // consensus of an untrained EC graph is the reference itself.
        testutil::check(10, |rng| {
            let __h0 = rng.range(5, 60);
            let data = testutil::random_seq(rng, __h0, 4);
            let reference = Sequence::from_symbols("r", data.clone());
            let g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
            let path = consensus(&g).unwrap();
            assert_eq!(path.consensus.data, data);
            let (m, i) = path_composition(&g, &path.states);
            assert_eq!(m, data.len());
            assert_eq!(i, 0);
        });
    }

    #[test]
    fn path_states_are_increasing() {
        let mut rng = XorShift::new(3);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 40, 4));
        let g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let path = consensus(&g).unwrap();
        for w in path.states.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn trained_graph_corrects_substitution_errors() {
        // End-to-end miniature of Apollo: an erroneous "assembly" is
        // trained with accurate reads; the consensus must move toward
        // the true sequence.
        let mut rng = XorShift::new(17);
        let true_seq =
            Sequence::from_symbols("true", testutil::random_seq(&mut rng, 60, 4));
        // Erroneous assembly: 10% substitutions.
        let mut assembly = true_seq.data.clone();
        let mut n_err = 0;
        for i in 0..assembly.len() {
            if rng.chance(0.10) {
                assembly[i] = (assembly[i] + 1 + rng.below(3) as u8) % 4;
                n_err += 1;
            }
        }
        assert!(n_err > 0);
        let assembly = Sequence::from_symbols("asm", assembly);
        let mut g = Phmm::error_correction(&assembly, &EcDesignParams::default()).unwrap();
        // Accurate reads drawn from the true sequence.
        let reads: Vec<Sequence> = (0..20)
            .map(|i| {
                simulate_read(
                    &mut rng,
                    &true_seq,
                    0,
                    true_seq.len(),
                    &ErrorProfile { sub: 0.01, ins: 0.01, del: 0.01, ins_ext: 0.1 },
                    i,
                )
                .seq
            })
            .collect();
        train(
            &mut g,
            &reads,
            &TrainConfig { max_iters: 3, tol: 0.0, ..Default::default() },
        )
        .unwrap();
        let decoded = consensus(&g).unwrap().consensus;
        // Hamming-ish distance over the aligned prefix.
        let dist = |a: &[u8], b: &[u8]| -> usize {
            let n = a.len().min(b.len());
            (0..n).filter(|&i| a[i] != b[i]).count() + a.len().abs_diff(b.len())
        };
        let before = dist(&assembly.data, &true_seq.data);
        let after = dist(&decoded.data, &true_seq.data);
        assert!(
            after < before,
            "correction failed: before={before} after={after}"
        );
    }

    #[test]
    fn rejects_silent_graphs() {
        use crate::phmm::{Profile, TraditionalParams};
        let seq = Sequence::from_str("r", "ACGT", crate::seq::DNA).unwrap();
        let profile = Profile::from_sequence(&seq, crate::seq::DNA, 0.9);
        let g = Phmm::traditional(&profile, &TraditionalParams::default()).unwrap();
        assert!(consensus(&g).is_err());
    }

    #[test]
    fn viterbi_path_decodes_exact_read() {
        // A noiseless read drawn from the reference should decode to a
        // pure match path of the read's length on an untrained EC graph.
        testutil::check(10, |rng| {
            let len = rng.range(5, 50);
            let data = testutil::random_seq(rng, len, 4);
            let reference = Sequence::from_symbols("r", data.clone());
            let g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
            let read = Sequence::from_symbols("read", data);
            let path = viterbi_path(&g, &read).unwrap();
            assert_eq!(path.states.len(), read.len());
            assert!(path.log_prob.is_finite());
            assert!(path.log_prob < 0.0);
            let (m, i) = path_composition(&g, &path.states);
            assert_eq!(m, read.len(), "expected all-match path");
            assert_eq!(i, 0);
            // Consecutive path states must be joined by CSR edges.
            for w in path.states.windows(2) {
                assert!(
                    g.outgoing(w[0] as usize).any(|(to, _)| to == w[1]),
                    "no edge {} -> {}",
                    w[0],
                    w[1]
                );
            }
        });
    }

    #[test]
    fn viterbi_path_is_deterministic() {
        let mut rng = XorShift::new(23);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 60, 4));
        let g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let read =
            simulate_read(&mut rng, &reference, 0, reference.len(), &ErrorProfile::pacbio(), 0)
                .seq;
        let a = viterbi_path(&g, &read).unwrap();
        let b = viterbi_path(&g, &read).unwrap();
        assert_eq!(a.states, b.states);
        assert_eq!(a.log_prob, b.log_prob);
    }

    #[test]
    fn viterbi_path_rejects_hostile_reads() {
        let mut rng = XorShift::new(29);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 30, 4));
        let g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let empty = Sequence::from_symbols("e", vec![]);
        assert!(matches!(viterbi_path(&g, &empty), Err(ApHmmError::Numerical(_))));
        let bad = Sequence::from_symbols("b", vec![0, 1, 99]);
        assert!(matches!(viterbi_path(&g, &bad), Err(ApHmmError::Numerical(_))));
    }
}
