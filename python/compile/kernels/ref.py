"""Pure-jnp correctness oracles for the banded Baum-Welch kernels.

The pHMM graph is encoded as a *forward band*: states are topologically
ordered (position-major), and every transition goes from state ``j`` to
state ``j + w`` with ``0 <= w < W``.  ``a_band[j, w]`` is the transition
probability ``P(j -> j+w)``; ``w == 0`` encodes self-loops (insertion
states of the traditional design).  Emissions are dense: ``emit[i, c]``.

These references define the semantics that both the Pallas kernels
(``forward.py``/``backward.py``) and the Rust native engine
(``rust/src/phmm/banded.rs`` + ``rust/src/baumwelch``) must match.
"""

import jax.numpy as jnp


def forward_step_ref(f_prev, a_band, e_col):
    """One banded forward step (Eq. 1 of the paper).

    ``out[i] = e_col[i] * sum_w f_prev[i-w] * a_band[i-w, w]``

    Args:
      f_prev: f32[N] scaled forward values at timestep t-1.
      a_band: f32[N, W] banded transition matrix.
      e_col:  f32[N] emission probabilities of the observed character.

    Returns:
      f32[N] unnormalized forward values at timestep t.
    """
    n, w_max = a_band.shape
    acc = f_prev * a_band[:, 0]
    for w in range(1, w_max):
        acc = acc.at[w:].add(f_prev[: n - w] * a_band[: n - w, w])
    return acc * e_col


def backward_step_ref(b_next, a_band, e_col_next):
    """One banded backward step (Eq. 2 of the paper).

    ``out[j] = sum_w a_band[j, w] * e_col_next[j+w] * b_next[j+w]``

    Returns unnormalized backward values at timestep t (caller divides by
    the forward scale c_{t+1}).
    """
    n, w_max = a_band.shape
    eb = e_col_next * b_next
    acc = a_band[:, 0] * eb
    for w in range(1, w_max):
        acc = acc.at[: n - w].add(a_band[: n - w, w] * eb[w:])
    return acc


def backward_xi_step_ref(f_t, b_next, a_band, e_col_next, c_next):
    """Fused backward + transition-numerator step.

    This is the software analogue of ApHMM's broadcast + partial-compute
    path: B_{t+1} values are consumed directly into the parameter-update
    numerators while the backward recurrence runs, so the full B matrix is
    never materialized.

    Returns:
      b_t:  f32[N]    scaled backward values at t.
      xi:   f32[N, W] with
            ``xi[j, w] = f_t[j] a[j,w] e_next[j+w] b_next[j+w] / c_next``
    """
    n, w_max = a_band.shape
    eb = e_col_next * b_next  # [N]
    cols = []
    for w in range(w_max):
        col = jnp.zeros((n,), dtype=a_band.dtype)
        col = col.at[: n - w].set(a_band[: n - w, w] * eb[w:])
        cols.append(col)
    m = jnp.stack(cols, axis=1)  # [N, W]
    b_t = jnp.sum(m, axis=1) / c_next
    xi = f_t[:, None] * m / c_next
    return b_t, xi
