"""Pallas kernel for the banded forward recurrence step (L1 hot spot).

This is ApHMM's PE-array computation re-thought for a TPU-style target
(DESIGN.md §Hardware-Adaptation): instead of per-state dot products over
incoming transitions (the paper's 4-lane PE design), the banded encoding
turns one timestep into W shifted elementwise FMAs over the state vector —
no gathers, fully vectorizable on the VPU.

The kernel tiles the state dimension; each tile reads its F_{t-1} slice
plus a (W-1)-element *halo* before it (the analogue of the paper's
PE-group partitioning with broadcasted boundary values).  Inputs are
pre-padded by the wrapper so tile 0 needs no branch.

Lowered with ``interpret=True``: real-TPU Mosaic custom-calls cannot run
on the CPU PJRT plugin; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default state-tile size.  VMEM estimate per grid step at f32:
#   f halo tile   (BT+W-1)        ~0.5 KB
#   a_band tile   (BT+W-1) * W    ~8 KB at BT=128, W=16
#   e tile + out  2 * BT          ~1 KB
# comfortably under a 64 KB VMEM budget per the DESIGN.md §Perf note.
DEFAULT_BLOCK = 128


def _forward_step_kernel(w_max, block, f_pad_ref, a_pad_ref, e_ref, o_ref):
    pid = pl.program_id(0)
    base = pid * block
    # Tile of F_{t-1} with leading halo: rows [base, base + block + W - 1)
    # of the padded array == states [base - (W-1), base + block) unpadded.
    f_loc = pl.load(f_pad_ref, (pl.dslice(base, block + w_max - 1),))
    acc = jnp.zeros((block,), dtype=f_loc.dtype)
    for w in range(w_max):
        # Source states j = i - w for targets i in this tile live at local
        # offset (W-1-w) .. (W-1-w)+block of the halo tile.
        lo = w_max - 1 - w
        f_src = jax.lax.dynamic_slice(f_loc, (lo,), (block,))
        a_src = pl.load(
            a_pad_ref, (pl.dslice(base + lo, block), pl.dslice(w, 1))
        )[:, 0]
        acc = acc + f_src * a_src
    e_tile = pl.load(e_ref, (pl.dslice(base, block),))
    pl.store(o_ref, (pl.dslice(base, block),), acc * e_tile)


@functools.partial(jax.jit, static_argnames=("block",))
def forward_step(f_prev, a_band, e_col, block=DEFAULT_BLOCK):
    """One banded forward step: ``out[i] = e[i] * sum_w f[i-w] a[i-w, w]``.

    Matches :func:`ref.forward_step_ref`.  N is padded up to a multiple of
    ``block``; the band is padded with W-1 leading zero rows so the first
    tile's halo reads are in-bounds.
    """
    n, w_max = a_band.shape
    n_pad = -(-n // block) * block
    halo = w_max - 1
    f_pad = jnp.zeros((halo + n_pad,), f_prev.dtype).at[halo : halo + n].set(f_prev)
    a_pad = jnp.zeros((halo + n_pad, w_max), a_band.dtype).at[halo : halo + n].set(
        a_band
    )
    e_pad = jnp.zeros((n_pad,), e_col.dtype).at[:n].set(e_col)
    out = pl.pallas_call(
        functools.partial(_forward_step_kernel, w_max, block),
        out_shape=jax.ShapeDtypeStruct((n_pad,), f_prev.dtype),
        grid=(n_pad // block,),
        interpret=True,
    )(f_pad, a_pad, e_pad)
    return out[:n]
