"""Pallas kernel for the fused backward + transition-update step (L1).

Implements ApHMM's *broadcast + partial compute* optimization (§4.3): the
backward values B̂_{t+1} are consumed directly into the transition-update
numerators (xi) in the same pass that produces B̂_t, so the full backward
matrix never exists in memory.  The shared factor

    m[j, w] = a_band[j, w] * e_next[j+w] * b_next[j+w]

is computed once per (j, w) and used for both outputs — the kernel-level
analogue of the paper's UT units consuming the PE broadcast bus.

Tiles read a *trailing* halo (states j+w up to j+W-1), mirroring the
forward kernel's leading halo.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _backward_xi_kernel(
    w_max, block, f_ref, eb_pad_ref, a_ref, cinv_ref, b_ref, xi_ref
):
    pid = pl.program_id(0)
    base = pid * block
    # eb tile with trailing halo: states [base, base + block + W - 1).
    eb_loc = pl.load(eb_pad_ref, (pl.dslice(base, block + w_max - 1),))
    cinv = cinv_ref[0]
    f_tile = pl.load(f_ref, (pl.dslice(base, block),))
    acc = jnp.zeros((block,), dtype=eb_loc.dtype)
    for w in range(w_max):
        a_col = pl.load(a_ref, (pl.dslice(base, block), pl.dslice(w, 1)))[:, 0]
        eb_shift = jax.lax.dynamic_slice(eb_loc, (w,), (block,))
        m = a_col * eb_shift
        acc = acc + m
        pl.store(
            xi_ref,
            (pl.dslice(base, block), pl.dslice(w, 1)),
            (f_tile * m * cinv)[:, None],
        )
    pl.store(b_ref, (pl.dslice(base, block),), acc * cinv)


@functools.partial(jax.jit, static_argnames=("block",))
def backward_xi_step(f_t, b_next, a_band, e_col_next, c_next, block=DEFAULT_BLOCK):
    """Fused backward + xi step; matches :func:`ref.backward_xi_step_ref`.

    Returns ``(b_t[N], xi[N, W])``.
    """
    n, w_max = a_band.shape
    n_pad = -(-n // block) * block
    halo = w_max - 1
    eb = e_col_next * b_next
    eb_pad = jnp.zeros((n_pad + halo,), eb.dtype).at[:n].set(eb)
    a_pad = jnp.zeros((n_pad, w_max), a_band.dtype).at[:n].set(a_band)
    f_pad = jnp.zeros((n_pad,), f_t.dtype).at[:n].set(f_t)
    cinv = jnp.reshape(1.0 / c_next, (1,)).astype(f_t.dtype)
    b_out, xi_out = pl.pallas_call(
        functools.partial(_backward_xi_kernel, w_max, block),
        out_shape=(
            jax.ShapeDtypeStruct((n_pad,), f_t.dtype),
            jax.ShapeDtypeStruct((n_pad, w_max), f_t.dtype),
        ),
        grid=(n_pad // block,),
        interpret=True,
    )(f_pad, eb_pad, a_pad, cinv)
    return b_out[:n], xi_out[:n]
