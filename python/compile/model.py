"""L2: the Baum-Welch computation over banded pHMMs, built on the L1
Pallas kernels, AOT-lowered once by ``aot.py`` and executed from Rust.

Three entry points (all shapes static at lowering time):

  * :func:`forward_scores` — scaled forward pass, returns the
    log-likelihood only (inference path: protein family search, MSA
    scoring).
  * :func:`baum_welch_sums` — one full Baum-Welch expectation pass,
    returning the *raw* update sums (xi, gamma denominators, emission
    numerators) so the Rust coordinator can accumulate across many reads
    before the maximization division (batch EM, exactly what Apollo does
    per chunk).
  * :func:`baum_welch_step` — expectation + maximization fused: returns
    the updated ``(a_band, emit)`` plus log-likelihood, for single-read
    training.

Numerics: per-timestep scaling (DESIGN.md §Numerics).  Sequences are
padded to the static length T; ``length`` masks padded timesteps so a
lowered executable serves any chunk ≤ T.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.forward import forward_step
from .kernels.backward import backward_xi_step
from .kernels import ref

EPS = 1e-30


def _emission_column(emit, s_t):
    """e_col[i] = emit[i, s_t] (gather of one emission column)."""
    return jnp.take(emit, s_t, axis=1)


def _forward_scan(a_band, emit, seq, f_init, length, use_pallas):
    """Scaled forward pass.

    Returns (f_hat[T, N], scales[T], loglik).  Masked timesteps carry
    f_hat through unchanged with scale 1 (log contribution 0).
    """
    step_fn = forward_step if use_pallas else ref.forward_step_ref

    e0 = _emission_column(emit, seq[0])
    f0_un = f_init * e0
    c0 = jnp.sum(f0_un) + EPS
    f0 = f0_un / c0

    def step(f_prev, t):
        e_col = _emission_column(emit, seq[t])
        f_un = step_fn(f_prev, a_band, e_col)
        c_t = jnp.sum(f_un) + EPS
        f_hat = f_un / c_t
        live = t < length
        f_out = jnp.where(live, f_hat, f_prev)
        c_out = jnp.where(live, c_t, 1.0)
        return f_out, (f_out, c_out)

    t_range = jnp.arange(1, seq.shape[0])
    _, (f_rest, c_rest) = jax.lax.scan(step, f0, t_range)
    f_all = jnp.concatenate([f0[None, :], f_rest], axis=0)
    scales = jnp.concatenate([jnp.reshape(c0, (1,)), c_rest], axis=0)
    loglik = jnp.sum(jnp.where(jnp.arange(seq.shape[0]) < length, jnp.log(scales), 0.0))
    return f_all, scales, loglik


def _backward_update_scan(a_band, emit, seq, f_all, scales, length, use_pallas):
    """Backward pass fused with update-sum accumulation.

    Walks t = T-1 .. 0.  At the effective last timestep (length-1) the
    scaled backward vector is all-ones; beyond it everything is masked.
    Accumulates:
      xi_sum[N, W]   transition numerators (Eq. 3 numerator)
      trans_den[N]   sum of gamma over t < length-1 (Eq. 3 denominator)
      e_num[N, S]    emission numerators (Eq. 4 numerator)
      gamma_den[N]   sum of gamma over t < length (Eq. 4 denominator)

    IMPLEMENTATION NOTE (AOT portability): everything per-timestep —
    emission columns of s_{t+1}, scales c_{t+1}, one-hot rows, and the
    0/1 masks derived from `length` — is pre-gathered *outside* the scan
    and fed through the scan's xs inputs, and the masking is arithmetic
    (multiply by 0/1) rather than scalar-predicated `where`.  Clamped
    dynamic gathers (`seq[min(t, T-2)+1]`) and scalar-threshold selects
    inside the loop body mis-execute after the HLO-text round-trip on
    xla_extension 0.5.1 (see DESIGN.md §Numerics and the parity test);
    the xs-based form lowers to the same constructs as the forward scan,
    which round-trips correctly.
    """
    t_len, n = f_all.shape
    n_sigma = emit.shape[1]
    step_fn = backward_xi_step if use_pallas else ref.backward_xi_step_ref
    last = length - 1
    w_max = a_band.shape[1]
    dtype = f_all.dtype

    ts = jnp.arange(t_len)
    # Per-t pre-gathered data (aligned to t), reversed so the scan walks
    # t = T-1 .. 0 by consuming xs in natural order.
    seq_next = jnp.roll(seq, -1)  # seq[t+1]; the t = T-1 row is masked out
    e_next = jnp.take(emit, seq_next, axis=1).T  # [T, N] emission cols at t+1
    c_next = jnp.roll(scales, -1)  # scales[t+1]; t = T-1 row masked
    onehot = jax.nn.one_hot(seq, n_sigma, dtype=dtype)  # [T, Σ]
    live = (ts <= last).astype(dtype)  # gamma mask
    live_xi = (ts < last).astype(dtype)  # xi mask
    is_last = (ts == last).astype(dtype)

    xs = (
        f_all[::-1],
        e_next[::-1],
        c_next[::-1],
        onehot[::-1],
        live[::-1],
        live_xi[::-1],
        is_last[::-1],
    )

    init = (
        jnp.ones((n,), dtype),
        jnp.zeros((n, w_max), dtype),
        jnp.zeros((n,), dtype),
        jnp.zeros((n, n_sigma), dtype),
        jnp.zeros((n,), dtype),
    )

    def step(carry, x):
        b_next, xi_sum, trans_den, e_num, gamma_den = carry
        f_t, e_col_next, c_n, oh, lv, lvx, isl = x
        c_safe = jnp.where(c_n == 0.0, jnp.asarray(1.0, dtype), c_n)
        b_rec, xi_t = step_fn(f_t, b_next, a_band, e_col_next, c_safe)
        # b_t = ones at t == last, recurrence below, carried above:
        # coefficients isl / (lv - isl) / (1 - lv) are disjoint 0/1.
        b_t = isl + (lv - isl) * b_rec + (1.0 - lv) * b_next
        xi_sum = xi_sum + lvx * xi_t
        gamma_t = f_t * b_t
        gamma_m = lv * gamma_t
        trans_den = trans_den + lvx * gamma_t
        gamma_den = gamma_den + gamma_m
        e_num = e_num + gamma_m[:, None] * oh[None, :]
        return (b_t, xi_sum, trans_den, e_num, gamma_den), None

    (_, xi_sum, trans_den, e_num, gamma_den), _ = jax.lax.scan(step, init, xs)
    return xi_sum, trans_den, e_num, gamma_den


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def forward_scores(a_band, emit, seq, f_init, length, use_pallas=True):
    """Inference scoring: log P(seq | pHMM) via the scaled forward pass."""
    _, _, loglik = _forward_scan(a_band, emit, seq, f_init, length, use_pallas)
    return (loglik,)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def baum_welch_sums(a_band, emit, seq, f_init, length, use_pallas=True):
    """One expectation pass; returns raw update sums + loglik.

    Returns (xi_sum[N,W], trans_den[N], e_num[N,S], gamma_den[N], loglik).
    """
    f_all, scales, loglik = _forward_scan(a_band, emit, seq, f_init, length, use_pallas)
    xi_sum, trans_den, e_num, gamma_den = _backward_update_scan(
        a_band, emit, seq, f_all, scales, length, use_pallas
    )
    return xi_sum, trans_den, e_num, gamma_den, loglik


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def baum_welch_step(a_band, emit, seq, f_init, length, use_pallas=True):
    """Expectation + maximization for a single sequence.

    States never reached (zero denominators) keep their old parameters.
    Returns (a_new[N,W], e_new[N,S], loglik).
    """
    xi_sum, trans_den, e_num, gamma_den, loglik = baum_welch_sums(
        a_band, emit, seq, f_init, length, use_pallas
    )
    a_new = jnp.where(trans_den[:, None] > EPS, xi_sum / (trans_den[:, None] + EPS), a_band)
    # Only redistribute where the state had outgoing mass to begin with.
    a_new = jnp.where(a_band > 0.0, a_new, a_band)
    e_new = jnp.where(gamma_den[:, None] > EPS, e_num / (gamma_den[:, None] + EPS), emit)
    return a_new, e_new, loglik
