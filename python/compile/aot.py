"""AOT pipeline: lower the L2 Baum-Welch entry points to HLO *text* for
the Rust PJRT runtime (``rust/src/runtime``).

HLO text — NOT ``lowered.compiler_ir("hlo").as_hlo_proto().serialize()`` —
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Each artifact is a fixed-shape executable.  A ``manifest.txt`` describes
every artifact (name, entry, shapes, argument order) so the Rust side can
validate buffers before execution.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (artifact name, entry point, N states, W band, sigma alphabet, T chunk)
# ec_*  : error-correction design (DNA, Sigma=4).  The default EC design
#         has W = (1+max_del)*(1+max_ins)+1 = 25; W=32 leaves headroom.
# pro_* : traditional design folded to an emitting band (protein,
#         Sigma=20).  Fold depth d gives W = 2*(1+d)+1 = 9 at d=3.
ARTIFACTS = [
    ("ec_bw_n512_w32_t128", "baum_welch_sums", 512, 32, 4, 128),
    ("ec_fwd_n512_w32_t128", "forward_scores", 512, 32, 4, 128),
    ("pro_fwd_n384_w12_t128", "forward_scores", 384, 12, 20, 128),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps a single tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(entry: str, n: int, w: int, sigma: int, t: int):
    fn = getattr(model, entry)
    a_spec = jax.ShapeDtypeStruct((n, w), jnp.float32)
    e_spec = jax.ShapeDtypeStruct((n, sigma), jnp.float32)
    s_spec = jax.ShapeDtypeStruct((t,), jnp.int32)
    f0_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    len_spec = jax.ShapeDtypeStruct((), jnp.int32)
    return fn.lower(a_spec, e_spec, s_spec, f0_spec, len_spec, use_pallas=True)


def result_arity(entry: str) -> int:
    return {"forward_scores": 1, "baum_welch_sums": 5, "baum_welch_step": 3}[entry]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact names to build"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest_lines = []
    for name, entry, n, w, sigma, t in ARTIFACTS:
        if only is not None and name not in only:
            continue
        lowered = lower_artifact(entry, n, w, sigma, t)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(
            f"{name} entry={entry} n={n} w={w} sigma={sigma} t={t} "
            f"args=a_band:f32[{n},{w}],emit:f32[{n},{sigma}],seq:i32[{t}],"
            f"f_init:f32[{n}],length:i32[] results={result_arity(entry)}"
        )
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
