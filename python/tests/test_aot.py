"""AOT pipeline tests: lowering, HLO-text emission, manifest integrity.

The Rust↔XLA numerical parity is covered by `rust/tests/xla_parity.rs`;
these tests keep the Python side of the contract honest — every artifact
lowers, the HLO text contains a parsable ENTRY with the expected
parameter shapes in the expected order, and the manifest describes
exactly what was lowered.
"""

import re

import pytest

from compile import aot


@pytest.mark.parametrize("artifact", aot.ARTIFACTS, ids=lambda a: a[0])
def test_artifact_lowers_to_hlo_text(artifact):
    name, entry, n, w, sigma, t = artifact
    lowered = aot.lower_artifact(entry, n, w, sigma, t)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    # Parameter shapes appear with the expected types and extents and
    # the expected parameter indices (the Rust runtime feeds buffers by
    # position — this IS the ABI).
    entry_block = text[text.index("ENTRY"):]
    params = dict(
        re.findall(r"(\w+\[[\d,]*\])[^\n]*? parameter\((\d+)\)", entry_block)
    )
    by_index = {int(v): k for k, v in params.items()}
    assert by_index[0] == f"f32[{n},{w}]", by_index
    assert by_index[1] == f"f32[{n},{sigma}]"
    assert by_index[2] == f"s32[{t}]"
    assert by_index[3] == f"f32[{n}]"
    assert by_index[4] == "s32[]"


def test_result_arity_matches_entry_points():
    assert aot.result_arity("forward_scores") == 1
    assert aot.result_arity("baum_welch_sums") == 5
    assert aot.result_arity("baum_welch_step") == 3


def test_no_dynamic_gather_in_backward_scan():
    """Regression guard for the xla_extension 0.5.1 round-trip hazard
    (DESIGN.md §Deviations): the backward scan must not contain clamped
    dynamic gathers or scalar-select masking — its xs must be
    pre-gathered.  We check the HLO has no `clamp` feeding a
    `dynamic-slice` inside a while body (the construct that
    mis-executed)."""
    name, entry, n, w, sigma, t = aot.ARTIFACTS[0]
    lowered = aot.lower_artifact(entry, n, w, sigma, t)
    text = aot.to_hlo_text(lowered)
    # The forward scan legitimately gathers seq[t]; the hazardous form
    # is clamp(...) -> dynamic-slice on the *scales/sequence* arrays
    # with an offset add.  Heuristic: no 'clamp' op should appear at
    # all in our lowering (we never emit jnp.minimum on indices now).
    assert text.count(" clamp(") <= 2, "unexpected clamped index gathers"


def test_manifest_written(tmp_path):
    import os
    import subprocess
    import sys

    out_dir = tmp_path / "arts"
    pkg_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # Only lower the smallest artifact to keep the test fast.
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out_dir),
            "--only",
            "pro_fwd_n384_w12_t128",
        ],
        check=True,
        cwd=pkg_root,
    )
    manifest = (out_dir / "manifest.txt").read_text()
    assert "pro_fwd_n384_w12_t128" in manifest
    assert "entry=forward_scores" in manifest
    assert (out_dir / "pro_fwd_n384_w12_t128.hlo.txt").exists()
