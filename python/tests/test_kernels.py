"""L1 kernel correctness: Pallas (interpret) vs pure-jnp ref vs numpy oracle.

Hypothesis sweeps shapes/dtypes; this is the CORE correctness signal for
the kernels that end up inside the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.forward import forward_step
from compile.kernels.backward import backward_xi_step

from . import oracle


def _rng(seed):
    return np.random.default_rng(seed)


def _case(seed, n, w_max, n_sigma=4):
    rng = _rng(seed)
    a_band, emit, f_init = oracle.random_banded_phmm(rng, n, w_max, n_sigma)
    f_prev = rng.uniform(0.0, 1.0, size=n)
    f_prev /= f_prev.sum()
    e_col = emit[:, rng.integers(n_sigma)]
    return a_band, f_prev, e_col


shape_strategy = st.tuples(
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
    st.integers(min_value=4, max_value=200),  # n
    st.integers(min_value=1, max_value=12),  # w_max
)


@settings(max_examples=40, deadline=None)
@given(shape_strategy)
def test_forward_step_pallas_matches_oracle(params):
    seed, n, w_max = params
    a_band, f_prev, e_col = _case(seed, n, w_max)
    got = forward_step(
        jnp.asarray(f_prev, jnp.float32),
        jnp.asarray(a_band, jnp.float32),
        jnp.asarray(e_col, jnp.float32),
    )
    dense = oracle.band_to_dense(a_band)
    want = (f_prev @ dense) * e_col
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=1e-7)


@settings(max_examples=40, deadline=None)
@given(shape_strategy)
def test_forward_step_pallas_matches_jnp_ref(params):
    seed, n, w_max = params
    a_band, f_prev, e_col = _case(seed, n, w_max)
    args = (
        jnp.asarray(f_prev, jnp.float32),
        jnp.asarray(a_band, jnp.float32),
        jnp.asarray(e_col, jnp.float32),
    )
    got = forward_step(*args)
    want = ref.forward_step_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-8)


@settings(max_examples=40, deadline=None)
@given(shape_strategy)
def test_backward_xi_pallas_matches_oracle(params):
    seed, n, w_max = params
    rng = _rng(seed)
    a_band, emit, _ = oracle.random_banded_phmm(rng, n, w_max, 4)
    b_next = rng.uniform(0.1, 1.0, size=n)
    f_t = rng.uniform(0.0, 1.0, size=n)
    f_t /= f_t.sum()
    e_col = emit[:, rng.integers(4)]
    c_next = float(rng.uniform(0.2, 1.5))

    b_got, xi_got = backward_xi_step(
        jnp.asarray(f_t, jnp.float32),
        jnp.asarray(b_next, jnp.float32),
        jnp.asarray(a_band, jnp.float32),
        jnp.asarray(e_col, jnp.float32),
        jnp.float32(c_next),
    )
    # Oracle: dense backward step + elementwise xi definition.
    dense = oracle.band_to_dense(a_band)
    b_want = (dense @ (e_col * b_next)) / c_next
    xi_want = np.zeros_like(a_band)
    for j in range(n):
        for w in range(w_max):
            i = j + w
            if i < n:
                xi_want[j, w] = f_t[j] * a_band[j, w] * e_col[i] * b_next[i] / c_next
    np.testing.assert_allclose(np.asarray(b_got), b_want, rtol=2e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(xi_got), xi_want, rtol=2e-5, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_backward_xi_row_sum_equals_b(params):
    """Invariant: sum_w xi[j, w] == f_t[j] * b_t[j] (gamma consistency)."""
    seed, n, w_max = params
    rng = _rng(seed)
    a_band, emit, _ = oracle.random_banded_phmm(rng, n, w_max, 4)
    b_next = rng.uniform(0.1, 1.0, size=n)
    f_t = rng.uniform(0.01, 1.0, size=n)
    e_col = emit[:, 0]
    b_got, xi_got = backward_xi_step(
        jnp.asarray(f_t, jnp.float32),
        jnp.asarray(b_next, jnp.float32),
        jnp.asarray(a_band, jnp.float32),
        jnp.asarray(e_col, jnp.float32),
        jnp.float32(1.0),
    )
    np.testing.assert_allclose(
        np.asarray(xi_got).sum(axis=1),
        np.asarray(b_got) * f_t,
        rtol=5e-5,
        atol=1e-7,
    )


@pytest.mark.parametrize("block", [8, 32, 128, 256])
def test_forward_step_block_sizes(block):
    """Tiling must not change results (halo handling across tile edges)."""
    a_band, f_prev, e_col = _case(7, 100, 9)
    args = (
        jnp.asarray(f_prev, jnp.float32),
        jnp.asarray(a_band, jnp.float32),
        jnp.asarray(e_col, jnp.float32),
    )
    want = np.asarray(ref.forward_step_ref(*args))
    got = np.asarray(forward_step(*args, block=block))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("block", [8, 32, 128])
def test_backward_xi_block_sizes(block):
    a_band, f_prev, e_col = _case(11, 77, 6)
    rng = _rng(11)
    b_next = rng.uniform(0.1, 1.0, size=77)
    args = (
        jnp.asarray(f_prev, jnp.float32),
        jnp.asarray(b_next, jnp.float32),
        jnp.asarray(a_band, jnp.float32),
        jnp.asarray(e_col, jnp.float32),
        jnp.float32(0.7),
    )
    b_want, xi_want = ref.backward_xi_step_ref(*args)
    b_got, xi_got = backward_xi_step(*args, block=block)
    np.testing.assert_allclose(np.asarray(b_got), np.asarray(b_want), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(xi_got), np.asarray(xi_want), rtol=1e-6)


def test_forward_step_w1_degenerates_to_elementwise():
    """W=1 band is a pure diagonal: out = f * a0 * e."""
    rng = _rng(3)
    n = 33
    f = rng.uniform(size=n)
    a = rng.uniform(size=(n, 1))
    e = rng.uniform(size=n)
    got = forward_step(
        jnp.asarray(f, jnp.float32), jnp.asarray(a, jnp.float32), jnp.asarray(e, jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(got), f * a[:, 0] * e, rtol=1e-6)


def test_forward_step_zero_band_is_zero():
    n = 16
    got = forward_step(
        jnp.ones((n,), jnp.float32),
        jnp.zeros((n, 4), jnp.float32),
        jnp.ones((n,), jnp.float32),
    )
    assert np.all(np.asarray(got) == 0.0)
