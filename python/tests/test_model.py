"""L2 model correctness: scaled/fused scans vs the float64 numpy oracle.

Covers: log-likelihood, Baum-Welch raw sums, masking (padding invariance),
the fused maximization step, and scaled-vs-probability-space consistency.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

from . import oracle


def _mk(seed, n, w_max, n_sigma, t_len):
    rng = np.random.default_rng(seed)
    a_band, emit, f_init = oracle.random_banded_phmm(rng, n, w_max, n_sigma)
    seq = rng.integers(0, n_sigma, size=t_len).astype(np.int32)
    return a_band, emit, f_init, seq


def _jx(a_band, emit, f_init, seq, t_pad=None):
    t_pad = t_pad if t_pad is not None else len(seq)
    seq_p = np.zeros(t_pad, dtype=np.int32)
    seq_p[: len(seq)] = seq
    return (
        jnp.asarray(a_band, jnp.float32),
        jnp.asarray(emit, jnp.float32),
        jnp.asarray(seq_p),
        jnp.asarray(f_init, jnp.float32),
        jnp.int32(len(seq)),
    )


case_strategy = st.tuples(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=8, max_value=64),  # n
    st.integers(min_value=2, max_value=8),  # w_max
    st.sampled_from([4, 20]),  # sigma
    st.integers(min_value=3, max_value=16),  # t
)


@settings(max_examples=30, deadline=None)
@given(case_strategy)
def test_forward_scores_loglik_matches_oracle(params):
    seed, n, w_max, n_sigma, t_len = params
    a_band, emit, f_init, seq = _mk(seed, n, w_max, n_sigma, t_len)
    dense = oracle.band_to_dense(a_band)
    f = oracle.forward_matrix(dense, emit, seq, f_init)
    p = f[-1].sum()
    if p <= 1e-12:  # unreachable sequence under this random graph
        return
    (got,) = model.forward_scores(*_jx(a_band, emit, f_init, seq))
    np.testing.assert_allclose(float(got), np.log(p), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(case_strategy)
def test_baum_welch_sums_match_oracle(params):
    seed, n, w_max, n_sigma, t_len = params
    a_band, emit, f_init, seq = _mk(seed, n, w_max, n_sigma, t_len)
    dense = oracle.band_to_dense(a_band)
    p = oracle.forward_matrix(dense, emit, seq, f_init)[-1].sum()
    if p <= 1e-12:
        return
    want = oracle.baum_welch_sums_oracle(a_band, emit, seq, f_init)
    got = model.baum_welch_sums(*_jx(a_band, emit, f_init, seq))
    names = ["xi_sum", "trans_den", "e_num", "gamma_den", "loglik"]
    for name, g, w in zip(names, got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=5e-4, atol=1e-5, err_msg=name
        )


@settings(max_examples=15, deadline=None)
@given(case_strategy)
def test_padding_invariance(params):
    """Masked executables must give identical results for padded input —
    this is what lets one AOT artifact serve any chunk <= T."""
    seed, n, w_max, n_sigma, t_len = params
    a_band, emit, f_init, seq = _mk(seed, n, w_max, n_sigma, t_len)
    dense = oracle.band_to_dense(a_band)
    if oracle.forward_matrix(dense, emit, seq, f_init)[-1].sum() <= 1e-12:
        return
    exact = model.baum_welch_sums(*_jx(a_band, emit, f_init, seq))
    padded = model.baum_welch_sums(*_jx(a_band, emit, f_init, seq, t_pad=t_len + 7))
    for g, w in zip(exact, padded):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-7)


def test_gamma_is_normalized_per_timestep():
    """Posterior state occupancies sum to 1 at every live timestep, so
    gamma_den must sum to `length` over all states."""
    a_band, emit, f_init, seq = _mk(5, 48, 5, 4, 12)
    _, _, _, gamma_den, _ = model.baum_welch_sums(*_jx(a_band, emit, f_init, seq))
    np.testing.assert_allclose(float(np.asarray(gamma_den).sum()), len(seq), rtol=1e-4)


def test_baum_welch_step_rows_are_stochastic():
    """After maximization, reached states have normalized transition rows
    and emission rows; untouched states keep their old parameters."""
    a_band, emit, f_init, seq = _mk(9, 64, 6, 4, 14)
    a_new, e_new, _ = model.baum_welch_step(*_jx(a_band, emit, f_init, seq))
    a_new = np.asarray(a_new, dtype=np.float64)
    e_new = np.asarray(e_new, dtype=np.float64)
    _, trans_den, _, gamma_den, _ = (
        np.asarray(x, np.float64) for x in model.baum_welch_sums(*_jx(a_band, emit, f_init, seq))
    )
    reached = trans_den > 1e-6
    rows = a_new[reached].sum(axis=1)
    np.testing.assert_allclose(rows, np.ones_like(rows), rtol=1e-3)
    untouched = gamma_den <= 1e-30
    np.testing.assert_allclose(e_new[untouched], emit[untouched], rtol=1e-6)


def test_training_increases_likelihood():
    """One EM step must not decrease the likelihood of the training
    sequence (the defining property of Baum-Welch)."""
    a_band, emit, f_init, seq = _mk(21, 40, 4, 4, 10)
    args = _jx(a_band, emit, f_init, seq)
    a_new, e_new, ll0 = model.baum_welch_step(*args)
    (ll1,) = model.forward_scores(a_new, e_new, args[2], args[3], args[4])
    assert float(ll1) >= float(ll0) - 1e-4, (float(ll0), float(ll1))


def test_em_monotonicity_multi_step():
    a_band, emit, f_init, seq = _mk(33, 32, 4, 4, 12)
    args = list(_jx(a_band, emit, f_init, seq))
    lls = []
    for _ in range(5):
        a_new, e_new, ll = model.baum_welch_step(*args)
        lls.append(float(ll))
        args[0], args[1] = a_new, e_new
    assert all(b >= a - 1e-4 for a, b in zip(lls, lls[1:])), lls


@pytest.mark.parametrize("use_pallas", [True, False])
def test_pallas_and_ref_paths_agree(use_pallas):
    a_band, emit, f_init, seq = _mk(2, 56, 7, 4, 11)
    got = model.baum_welch_sums(*_jx(a_band, emit, f_init, seq), use_pallas=use_pallas)
    want = model.baum_welch_sums(*_jx(a_band, emit, f_init, seq), use_pallas=not use_pallas)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-7)


def test_length_one_sequence():
    """Degenerate chunk: no transitions, only emission statistics."""
    a_band, emit, f_init, seq = _mk(4, 24, 4, 4, 1)
    xi, trans_den, e_num, gamma_den, ll = model.baum_welch_sums(
        *_jx(a_band, emit, f_init, seq, t_pad=8)
    )
    assert float(np.abs(np.asarray(xi)).sum()) == 0.0
    assert float(np.asarray(trans_den).sum()) == 0.0
    np.testing.assert_allclose(float(np.asarray(gamma_den).sum()), 1.0, rtol=1e-5)
