"""Independent float64 numpy oracle for the banded Baum-Welch.

Deliberately written in plain probability space (no scaling, no fusion,
no banded shift tricks beyond the definition) so it shares no code or
structure with the implementations under test.  Only valid for short
sequences (no underflow protection) — tests keep T small.
"""

import numpy as np


def band_to_dense(a_band):
    n, w_max = a_band.shape
    dense = np.zeros((n, n), dtype=np.float64)
    for j in range(n):
        for w in range(w_max):
            if j + w < n:
                dense[j, j + w] = a_band[j, w]
    return dense


def forward_matrix(a_dense, emit, seq, f_init):
    """F[t, i] in probability space (Eq. 1)."""
    t_len = len(seq)
    n = a_dense.shape[0]
    f = np.zeros((t_len, n), dtype=np.float64)
    f[0] = f_init * emit[:, seq[0]]
    for t in range(1, t_len):
        f[t] = (f[t - 1] @ a_dense) * emit[:, seq[t]]
    return f


def backward_matrix(a_dense, emit, seq):
    """B[t, i] in probability space (Eq. 2), B[T-1] = 1."""
    t_len = len(seq)
    n = a_dense.shape[0]
    b = np.zeros((t_len, n), dtype=np.float64)
    b[t_len - 1] = 1.0
    for t in range(t_len - 2, -1, -1):
        b[t] = a_dense @ (emit[:, seq[t + 1]] * b[t + 1])
    return b


def baum_welch_sums_oracle(a_band, emit, seq, f_init):
    """Raw update sums exactly as model.baum_welch_sums defines them,
    normalized to the scaled convention (gamma_t sums to... actually the
    scaled outputs are xi_t = Xi_t / P and gamma_t = Gamma_t / P)."""
    a_band = np.asarray(a_band, dtype=np.float64)
    emit = np.asarray(emit, dtype=np.float64)
    f_init = np.asarray(f_init, dtype=np.float64)
    n, w_max = a_band.shape
    n_sigma = emit.shape[1]
    t_len = len(seq)
    a_dense = band_to_dense(a_band)
    f = forward_matrix(a_dense, emit, seq, f_init)
    b = backward_matrix(a_dense, emit, seq)
    p = f[t_len - 1].sum()

    xi_sum = np.zeros((n, w_max), dtype=np.float64)
    for t in range(t_len - 1):
        for j in range(n):
            for w in range(w_max):
                i = j + w
                if i < n and a_band[j, w] > 0:
                    xi_sum[j, w] += (
                        f[t, j] * a_band[j, w] * emit[i, seq[t + 1]] * b[t + 1, i]
                    )
    xi_sum /= p

    gamma = f * b / p  # [T, N]
    trans_den = gamma[: t_len - 1].sum(axis=0)
    gamma_den = gamma.sum(axis=0)
    e_num = np.zeros((n, n_sigma), dtype=np.float64)
    for t in range(t_len):
        e_num[:, seq[t]] += gamma[t]
    loglik = np.log(p)
    return xi_sum, trans_den, e_num, gamma_den, loglik


def random_banded_phmm(rng, n, w_max, n_sigma, terminal_tail=1):
    """Random normalized banded pHMM.  The last `terminal_tail` states have
    no outgoing transitions (terminal), mirroring real chunk graphs."""
    a_band = rng.uniform(0.05, 1.0, size=(n, w_max)).astype(np.float64)
    # Zero out entries that would leave the state space.
    for j in range(n):
        for w in range(w_max):
            if j + w >= n:
                a_band[j, w] = 0.0
    a_band[n - terminal_tail :, :] = 0.0
    # Sparsify a little so zero-transitions are exercised.
    mask = rng.uniform(size=a_band.shape) < 0.25
    a_band[mask] = 0.0
    row = a_band.sum(axis=1, keepdims=True)
    nz = row[:, 0] > 0
    a_band[nz] /= row[nz]
    emit = rng.uniform(0.05, 1.0, size=(n, n_sigma))
    emit /= emit.sum(axis=1, keepdims=True)
    f_init = np.zeros(n)
    k = max(1, n // 8)
    f_init[:k] = rng.uniform(0.1, 1.0, size=k)
    f_init /= f_init.sum()
    return a_band, emit, f_init
